//! Hardware model of the paper's testbed (§4.1).

use crate::transport::LinkModel;

/// GPU roofline constants (decode is memory-bandwidth-bound; verification
/// of wider blocks adds a compute term).
#[derive(Debug, Clone, Copy)]
pub struct GpuModel {
    pub name: &'static str,
    /// Memory bandwidth, bytes/s.
    pub mem_bw: f64,
    /// Dense f16 peak, FLOP/s (with a practical efficiency factor applied).
    pub flops: f64,
}

impl GpuModel {
    pub const RTX3090: GpuModel = GpuModel {
        name: "RTX3090",
        mem_bw: 936e9,
        flops: 71e12 * 0.45,
    };
    pub const RTX4090: GpuModel = GpuModel {
        name: "RTX4090",
        mem_bw: 1008e9,
        flops: 165e12 * 0.45,
    };
    pub const L40: GpuModel = GpuModel {
        name: "L40",
        mem_bw: 864e9,
        flops: 181e12 * 0.45,
    };
}

/// One pipeline stage: a parameter slice resident on one GPU.
#[derive(Debug, Clone, Copy)]
pub struct StageModel {
    pub gpu: GpuModel,
    /// Bytes of parameters this stage must stream per forward.
    pub params_bytes: f64,
}

impl StageModel {
    /// Seconds to process a block of `width` tokens once: parameter
    /// streaming (memory-bound floor) plus the width-dependent compute term
    /// — the paper's compensation factor C emerges from this sum.
    pub fn block_time(&self, width: usize) -> f64 {
        let stream = self.params_bytes / self.gpu.mem_bw;
        let compute = width as f64 * 2.0 * (self.params_bytes / 2.0) / self.gpu.flops;
        stream + compute + 50e-6 // kernel-launch overhead
    }
}

/// The simulated deployment.
#[derive(Debug, Clone)]
pub struct ClusterSpec {
    pub stages: Vec<StageModel>,
    pub link: LinkModel,
    /// Draft node (dedicated L40 in the paper).
    pub draft: StageModel,
    /// Hidden size of the served model (activation bytes = hidden * 2).
    pub hidden_dim: usize,
}

impl ClusterSpec {
    /// 70B f16 (~141 GB) split over `n` RTX 3090 stages, 10 Gbps Ethernet,
    /// LLaMA 3.2 1B draft on an L40 — the paper's two-server deployment
    /// generalized to n stages (7 / 14 / 21 in Fig. 5).
    pub fn paper(n: usize) -> Self {
        let total_params = 70.6e9 * 2.0;
        let per = total_params / n as f64;
        Self {
            stages: vec![
                StageModel {
                    gpu: GpuModel::RTX3090,
                    params_bytes: per,
                };
                n
            ],
            link: LinkModel::ethernet_10g(),
            draft: StageModel {
                gpu: GpuModel::L40,
                params_bytes: 1.24e9 * 2.0,
            },
            hidden_dim: 8192,
        }
    }

    /// The SLM comparison point: 8B on a single L40.
    pub fn slm_8b() -> StageModel {
        StageModel {
            gpu: GpuModel::L40,
            params_bytes: 8.0e9 * 2.0,
        }
    }

    /// Activation transfer bytes for a block of `width` tokens (f16).
    pub fn activation_bytes(&self, width: usize) -> usize {
        width * self.hidden_dim * 2
    }

    /// Max stage block time for a given width.
    pub fn max_stage_time(&self, width: usize) -> f64 {
        self.stages
            .iter()
            .map(|s| s.block_time(width))
            .fold(0.0, f64::max)
    }

    pub fn sum_stage_time(&self, width: usize) -> f64 {
        self.stages.iter().map(|s| s.block_time(width)).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_pp_latency_magnitude() {
        // 14-stage PP: ~10 GB per 3090 -> ~11 ms/stage; full pass with
        // ethernet hops should land in the 150-350 ms/token band the paper's
        // PP baseline implies.
        let c = ClusterSpec::paper(14);
        let per_token = c.sum_stage_time(1)
            + 13.0 * c.link.transfer_time(c.activation_bytes(1));
        assert!(
            (0.10..0.40).contains(&per_token),
            "PP token latency {per_token}"
        );
    }

    #[test]
    fn wider_blocks_cost_more_but_sublinearly() {
        let c = ClusterSpec::paper(14);
        let t1 = c.max_stage_time(1);
        let t32 = c.max_stage_time(32);
        assert!(t32 > t1);
        assert!(t32 < t1 * 4.0, "memory-bound: 32x width must be << 32x time");
    }

    #[test]
    fn draft_is_much_faster_than_a_stage() {
        let c = ClusterSpec::paper(14);
        assert!(c.draft.block_time(32) < c.max_stage_time(32));
    }

    #[test]
    fn slm_8b_token_time_close_to_paper_8b() {
        // 16 GB / 864 GB/s ~ 18.5 ms
        let t = ClusterSpec::slm_8b().block_time(1);
        assert!((0.015..0.025).contains(&t), "slm token {t}");
    }
}
