//! Decoding policies on the simulated cluster: PipeDec, STPP, PP, SLM.
//!
//! All simulators decode `n_tokens` of one request and return the elapsed
//! model time; randomness (hit/miss draws) comes from the crate RNG so runs
//! are reproducible.

use super::cluster::ClusterSpec;
use super::hitmodel::HitModel;
use crate::util::XorShiftRng;

#[derive(Debug, Clone, Copy)]
pub struct SimOutcome {
    pub tokens: usize,
    pub seconds: f64,
    pub hits: u64,
    pub misses: u64,
    /// Average accepted length per STPP round (0 for others).
    pub accepted_per_round: f64,
}

impl SimOutcome {
    pub fn s_per_token(&self) -> f64 {
        self.seconds / self.tokens.max(1) as f64
    }

    pub fn accuracy(&self) -> f64 {
        let t = self.hits + self.misses;
        if t == 0 {
            0.0
        } else {
            self.hits as f64 / t as f64
        }
    }
}

/// PipeDec (§3): one timestep per pipeline beat. While predictions hit, one
/// token syncs per timestep; a miss restarts the pipeline (the next token
/// needs a full traversal). Deeper trees than the pipeline keep every stage
/// busy, so the beat is `max(T_draft, max_i T_stage(w) + T_link)` — the
/// paper's §2.4 latency formula.
pub fn simulate_pipedec(
    cluster: &ClusterSpec,
    width: usize,
    children: usize,
    hit: &HitModel,
    n_tokens: usize,
    rng: &mut XorShiftRng,
) -> SimOutcome {
    let n = cluster.stages.len();
    let t_stage = cluster.max_stage_time(width);
    let t_link = cluster.link.transfer_time(cluster.activation_bytes(width));
    let t_draft = cluster.draft.block_time(width * children.min(4));
    let beat = t_draft.max(t_stage + t_link);
    // pipeline fill after a (re)start: the root data flow must traverse all
    // stages before the first sync
    let fill = cluster.sum_stage_time(width)
        + (n.saturating_sub(1)) as f64 * t_link;

    let p = hit.hit_prob(width, children);
    let mut seconds = fill; // initial fill
    let (mut hits, mut misses) = (0u64, 0u64);
    let mut produced = 0usize;
    while produced < n_tokens {
        seconds += beat;
        produced += 1; // every sync decodes exactly one token (§3.4.3)
        if rng.chance(p) {
            hits += 1;
        } else {
            misses += 1;
            seconds += fill; // restart: in-flight flows invalidated
        }
    }
    SimOutcome {
        tokens: produced,
        seconds,
        hits,
        misses,
        accepted_per_round: 0.0,
    }
}

/// STPP (SpecInfer-style, §4.2): serial draft builds a static tree of
/// `depth` levels bounded to one verification batch, then one full pipeline
/// pass verifies it; the matched root path is accepted.
pub fn simulate_stpp(
    cluster: &ClusterSpec,
    tree_nodes: usize,
    children: usize,
    depth: usize,
    hit: &HitModel,
    n_tokens: usize,
    rng: &mut XorShiftRng,
) -> SimOutcome {
    let n = cluster.stages.len();
    let per_level_width = (tree_nodes / depth.max(1)).max(1);
    let t_draft_level = cluster.draft.block_time(per_level_width);
    let t_pass = cluster.sum_stage_time(tree_nodes)
        + (n.saturating_sub(1)) as f64
            * cluster.link.transfer_time(cluster.activation_bytes(tree_nodes));
    let round_time = depth as f64 * t_draft_level + t_pass;

    let p = hit.hit_prob(per_level_width, children);
    let mut seconds = 0.0;
    let (mut hits, mut misses) = (0u64, 0u64);
    let mut produced = 0usize;
    let mut rounds = 0u64;
    while produced < n_tokens {
        rounds += 1;
        seconds += round_time;
        // walk: each level matches with probability p; always >= 1 token
        let mut accepted = 1usize;
        while accepted < depth && rng.chance(p) {
            accepted += 1;
            hits += 1;
        }
        if accepted < depth {
            misses += 1;
        }
        produced += accepted;
    }
    SimOutcome {
        tokens: produced,
        seconds,
        hits,
        misses,
        accepted_per_round: produced as f64 / rounds.max(1) as f64,
    }
}

/// PP (§2.4): one token per full pipeline traversal.
pub fn simulate_pp(cluster: &ClusterSpec, n_tokens: usize) -> SimOutcome {
    let n = cluster.stages.len();
    let per_token = cluster.sum_stage_time(1)
        + (n.saturating_sub(1)) as f64
            * cluster.link.transfer_time(cluster.activation_bytes(1));
    SimOutcome {
        tokens: n_tokens,
        seconds: per_token * n_tokens as f64,
        hits: 0,
        misses: 0,
        accepted_per_round: 0.0,
    }
}

/// SLM: small model, one GPU, plain autoregression.
pub fn simulate_slm(n_tokens: usize) -> SimOutcome {
    let t = ClusterSpec::slm_8b().block_time(1);
    SimOutcome {
        tokens: n_tokens,
        seconds: t * n_tokens as f64,
        hits: 0,
        misses: 0,
        accepted_per_round: 0.0,
    }
}

/// Fig. 8 throughput model: `k` concurrent requests, per-GPU free memory
/// capping the batch at `max_batch`. PP/STPP interleave batched requests
/// across pipeline stages (throughput scales with batch until the cap);
/// PipeDec dedicates the whole pipeline to one request at a time but decodes
/// it faster.
pub fn throughput_tokens_per_s(
    cluster: &ClusterSpec,
    policy: &str,
    k: usize,
    max_batch: usize,
    hit: &HitModel,
    width: usize,
    children: usize,
    rng: &mut XorShiftRng,
) -> f64 {
    let b = k.min(max_batch).max(1);
    match policy {
        "pp" => {
            // batched pipeline: one batch of b tokens per beat once full
            let beat = cluster.max_stage_time(b)
                + cluster.link.transfer_time(cluster.activation_bytes(b));
            // can only overlap as many requests as stages
            let occupancy =
                (k.min(cluster.stages.len()) as f64 / cluster.stages.len() as f64).min(1.0);
            b as f64 / beat * occupancy
        }
        "stpp" => {
            let o = simulate_stpp(cluster, 16.min(b * 4), children, 4, hit, 256, rng);
            let per_req = 1.0 / o.s_per_token();
            // verification batch shares the block: b requests take turns
            per_req * (b as f64).sqrt()
        }
        "pipedec" => {
            let o = simulate_pipedec(cluster, width, children, hit, 256, rng);
            // single-task: throughput == single-request rate regardless of k
            1.0 / o.s_per_token()
        }
        _ => 0.0,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rng() -> XorShiftRng {
        XorShiftRng::new(42)
    }

    #[test]
    fn pipedec_beats_pp_at_paper_scale() {
        let c = ClusterSpec::paper(14);
        let hit = HitModel::default_for("math");
        let pd = simulate_pipedec(&c, 32, 16, &hit, 512, &mut rng());
        let pp = simulate_pp(&c, 512);
        let speedup = pp.s_per_token() / pd.s_per_token();
        assert!(
            (3.0..10.0).contains(&speedup),
            "PipeDec/PP speedup {speedup:.2} outside the paper's 4.46-7.79 band"
        );
    }

    #[test]
    fn pipedec_beats_stpp() {
        let c = ClusterSpec::paper(14);
        let hit = HitModel::default_for("math");
        let pd = simulate_pipedec(&c, 32, 16, &hit, 512, &mut rng());
        let st = simulate_stpp(&c, 16, 4, 4, &hit, 512, &mut rng());
        let speedup = st.s_per_token() / pd.s_per_token();
        assert!(
            (1.5..4.0).contains(&speedup),
            "PipeDec/STPP speedup {speedup:.2} outside the paper's 2.2-2.69 band"
        );
    }

    #[test]
    fn deeper_pipeline_helps_then_plateaus() {
        let hit = HitModel::default_for("math");
        let t7 = simulate_pipedec(&ClusterSpec::paper(7), 32, 16, &hit, 512, &mut rng())
            .s_per_token();
        let t14 = simulate_pipedec(&ClusterSpec::paper(14), 32, 16, &hit, 512, &mut rng())
            .s_per_token();
        assert!(t14 < t7, "14-stage should beat 7-stage");
        let gain = t7 / t14;
        assert!((1.2..2.2).contains(&gain), "7->14 gain {gain:.2} (paper ~1.64)");
    }

    #[test]
    fn pipedec_14_stage_near_slm() {
        // the paper's headline: the 70B pipeline approaches the 8B-on-one-GPU
        // latency
        let hit = HitModel::default_for("code");
        let pd = simulate_pipedec(&ClusterSpec::paper(14), 32, 16, &hit, 512, &mut rng());
        let slm = simulate_slm(512);
        let ratio = pd.s_per_token() / slm.s_per_token();
        assert!(ratio < 2.5, "PipeDec-14 vs SLM ratio {ratio:.2}");
    }

    #[test]
    fn stpp_accepts_more_with_accurate_draft() {
        let c = ClusterSpec::paper(14);
        let good = HitModel { a1: 0.95, rho: 0.6, beta: 2.5 };
        let bad = HitModel { a1: 0.30, rho: 0.6, beta: 2.5 };
        let a = simulate_stpp(&c, 16, 4, 4, &good, 256, &mut rng());
        let b = simulate_stpp(&c, 16, 4, 4, &bad, 256, &mut rng());
        assert!(a.accepted_per_round > b.accepted_per_round);
    }

    #[test]
    fn throughput_pp_wins_at_high_concurrency() {
        let c = ClusterSpec::paper(14);
        let hit = HitModel::default_for("math");
        let pp8 = throughput_tokens_per_s(&c, "pp", 8, 8, &hit, 32, 16, &mut rng());
        let pd8 = throughput_tokens_per_s(&c, "pipedec", 8, 8, &hit, 32, 16, &mut rng());
        let pd1 = throughput_tokens_per_s(&c, "pipedec", 1, 8, &hit, 32, 16, &mut rng());
        let pp1 = throughput_tokens_per_s(&c, "pp", 1, 8, &hit, 32, 16, &mut rng());
        assert!(pp8 > pd8, "PP should win at k=8 (pp {pp8:.1} vs pd {pd8:.1})");
        assert!(pd1 > pp1, "PipeDec should win at k=1 (pd {pd1:.1} vs pp {pp1:.1})");
    }
}
