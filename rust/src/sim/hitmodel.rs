//! Draft-hit statistics for the simulator.
//!
//! A PipeDec sync is a *hit* when the target's verified token is among the
//! retained children of the current root (§3.3.4). The probability is
//! modeled as
//!
//! ```text
//!   p(w, c) = A(c) · w / (w + beta)
//! ```
//!
//! where `A(k) = 1 - (1 - a1) · rho^(k-1)` is the draft's top-k agreement
//! curve (the paper's Fig. 3 "scale effect": top-8 accuracy approaches 1)
//! and the width factor models survival of the needed child under the
//! global top-w cumulative-probability pruning. `a1`/`rho`/`beta` are
//! calibrated per workload domain from accept rates *measured on the real
//! artifact-backed engine* (see the fig benches), then extrapolated to
//! paper-scale tree widths (64, 128) beyond the artifact caps.

#[derive(Debug, Clone, Copy)]
pub struct HitModel {
    /// Top-1 draft/target agreement.
    pub a1: f64,
    /// Geometric decay of the residual error with k.
    pub rho: f64,
    /// Width-retention half-point.
    pub beta: f64,
}

impl HitModel {
    /// Fixed default roughly matching a co-trained draft.
    pub fn default_for(domain: &str) -> Self {
        let a1 = match domain {
            "code" => 0.92,
            "math" => 0.90,
            "translate" => 0.88,
            "reading" => 0.85,
            "qa" => 0.80,
            "trivia" => 0.76,
            _ => 0.85,
        };
        Self {
            a1,
            rho: 0.60,
            beta: 2.5,
        }
    }

    /// Fit `a1` so that `p(w, c)` reproduces an accept rate measured on the
    /// real engine at (w, c); `rho`/`beta` keep their priors.
    pub fn calibrated(measured_accept: f64, w: usize, c: usize) -> Self {
        let mut m = Self {
            a1: 0.5,
            rho: 0.60,
            beta: 2.5,
        };
        // invert p = A(c) * w/(w+beta) for a1; if the measured rate exceeds
        // what the width prior admits even at A(c)=1, shrink beta instead.
        let width_f = w as f64 / (w as f64 + m.beta);
        if measured_accept >= 0.995 * width_f {
            m.a1 = 0.995;
            let a_c = m.topk(c);
            m.beta = (w as f64 * (a_c / measured_accept.min(0.999) - 1.0)).max(0.0);
            return m;
        }
        let target_a = (measured_accept / width_f).clamp(0.01, 0.999);
        // A(c) = 1 - (1-a1) rho^(c-1)  =>  a1 = 1 - (1 - A)/rho^(c-1)
        let denom = m.rho.powi(c as i32 - 1);
        m.a1 = (1.0 - (1.0 - target_a) / denom).clamp(0.01, 0.999);
        m
    }

    /// Top-k agreement A(k).
    pub fn topk(&self, k: usize) -> f64 {
        1.0 - (1.0 - self.a1) * self.rho.powi(k as i32 - 1)
    }

    /// Hit probability for tree parameters (w, c).
    pub fn hit_prob(&self, width: usize, children: usize) -> f64 {
        let a = self.topk(children.max(1));
        let wf = width as f64 / (width as f64 + self.beta);
        (a * wf).clamp(0.0, 1.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn topk_monotone_saturating() {
        let m = HitModel::default_for("math");
        let mut prev = 0.0;
        for k in 1..=16 {
            let a = m.topk(k);
            assert!(a >= prev);
            prev = a;
        }
        assert!(m.topk(8) > 0.97, "top-8 should approach 1 (paper Fig. 3)");
    }

    #[test]
    fn width_helps() {
        let m = HitModel::default_for("qa");
        assert!(m.hit_prob(32, 8) > m.hit_prob(8, 8));
        assert!(m.hit_prob(128, 8) <= 1.0);
    }

    #[test]
    fn calibration_roundtrips() {
        let measured = 0.85;
        let m = HitModel::calibrated(measured, 8, 8);
        let p = m.hit_prob(8, 8);
        assert!(
            (p - measured).abs() < 0.02,
            "calibrated p {p} vs measured {measured}"
        );
    }

    #[test]
    fn domains_are_ordered_by_predictability() {
        let code = HitModel::default_for("code").hit_prob(32, 16);
        let trivia = HitModel::default_for("trivia").hit_prob(32, 16);
        assert!(code > trivia);
    }
}
