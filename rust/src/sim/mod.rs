//! Calibrated cluster simulator (DESIGN.md inventory row 12).
//!
//! The paper's testbed — LLaMA 3.1-70B over 14–21 GPUs (RTX 3090/4090, L40)
//! on 10 Gbps Ethernet with a dedicated L40 draft node — is not available
//! here, so paper-scale latency/throughput figures (Figs. 4–8) are
//! regenerated on a discrete-time simulator whose two inputs are:
//!
//! 1. **hardware constants**: per-GPU memory bandwidth and compute peaks
//!    (decode is memory-bound; batch adds a compute term), plus the link
//!    model from [`crate::transport`];
//! 2. **hit statistics**: the draft/target top-k agreement measured on the
//!    *real* artifact-backed engine per workload domain, extrapolated along
//!    a saturating top-k curve for tree sizes beyond the artifact caps.
//!
//! Policies mirror the four engines: PipeDec timestep pipelining with
//! miss-restart, STPP serial-draft rounds, PP token-at-a-time, SLM
//! single-GPU autoregression.

pub mod cluster;
pub mod hitmodel;
pub mod policy;

pub use cluster::{ClusterSpec, GpuModel, StageModel};
pub use hitmodel::HitModel;
pub use policy::{simulate_pipedec, simulate_pp, simulate_slm, simulate_stpp,
    throughput_tokens_per_s, SimOutcome};
