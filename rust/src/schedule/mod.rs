//! Transmission scheduling (paper Appendix A, Algorithms 2–3).
//!
//! A central scheduler coordinates point-to-point transfers: it keeps a
//! bitmap of busy endpoints, a pending queue, and a finish queue. A transfer
//! is dispatched only when both its source and destination are free, which
//! serializes conflicting transfers while letting disjoint pairs proceed in
//! parallel — exactly the NCCL-relay discipline the paper describes.
//!
//! [`CentralScheduler::tick`] performs one scheduling round (release
//! completed tasks, dispatch eligible pending ones); the engine drives it
//! whenever a transfer is enqueued or finishes. [`node_logic`] mirrors
//! Algorithm 3's per-node sender/receiver behaviour and is exercised by the
//! transport layer.

use std::collections::{HashSet, VecDeque};

/// One point-to-point transfer order.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TransferTask {
    pub id: u64,
    pub src: usize,
    pub dst: usize,
    pub bytes: usize,
    pub seq: u64,
}

/// Dispatch record handed to the transport layer: the same task is pushed
/// to both endpoints' transport queues (Algorithm 2, lines 15–16).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Dispatch {
    pub task: TransferTask,
}

#[derive(Debug, Default)]
pub struct CentralScheduler {
    /// Busy endpoints (the paper's bitmap).
    bitmap: HashSet<usize>,
    pending: VecDeque<TransferTask>,
    finish: VecDeque<u64>,
    /// In-flight transfers by id (for release bookkeeping).
    inflight: Vec<TransferTask>,
    next_id: u64,
}

impl CentralScheduler {
    pub fn new() -> Self {
        Self::default()
    }

    /// Enqueue a transfer; returns its id.
    pub fn submit(&mut self, src: usize, dst: usize, bytes: usize, seq: u64) -> u64 {
        assert_ne!(src, dst, "self-transfer");
        let id = self.next_id;
        self.next_id += 1;
        self.pending.push_back(TransferTask {
            id,
            src,
            dst,
            bytes,
            seq,
        });
        id
    }

    /// Report a completed transfer (Algorithm 3: receiver notifies the
    /// finish queue).
    pub fn notify_finish(&mut self, id: u64) {
        self.finish.push_back(id);
    }

    /// One scheduling round (Algorithm 2 body): release endpoints of
    /// finished tasks, then dispatch every pending task whose endpoints are
    /// both free. Returns the dispatched tasks in order.
    pub fn tick(&mut self) -> Vec<Dispatch> {
        // release
        while let Some(id) = self.finish.pop_front() {
            if let Some(i) = self.inflight.iter().position(|t| t.id == id) {
                let t = self.inflight.swap_remove(i);
                self.bitmap.remove(&t.src);
                self.bitmap.remove(&t.dst);
            }
        }
        // dispatch
        let mut out = Vec::new();
        let mut remaining = VecDeque::new();
        while let Some(task) = self.pending.pop_front() {
            if self.bitmap.contains(&task.src) || self.bitmap.contains(&task.dst) {
                remaining.push_back(task);
                continue;
            }
            self.bitmap.insert(task.src);
            self.bitmap.insert(task.dst);
            self.inflight.push(task);
            out.push(Dispatch { task });
        }
        self.pending = remaining;
        out
    }

    pub fn pending_count(&self) -> usize {
        self.pending.len()
    }

    pub fn inflight_count(&self) -> usize {
        self.inflight.len()
    }

    pub fn is_idle(&self) -> bool {
        self.pending.is_empty() && self.inflight.is_empty()
    }

    /// Invariant: no endpoint participates in two in-flight transfers.
    pub fn check_no_conflicts(&self) -> Result<(), String> {
        let mut seen = HashSet::new();
        for t in &self.inflight {
            if !seen.insert(t.src) {
                return Err(format!("endpoint {} double-booked (src)", t.src));
            }
            if !seen.insert(t.dst) {
                return Err(format!("endpoint {} double-booked (dst)", t.dst));
            }
        }
        Ok(())
    }
}

/// Algorithm 3: what a compute node does with a dispatched task.
#[derive(Debug, PartialEq, Eq)]
pub enum NodeAction {
    /// Load tensor from cache, send to dst, clear cache entry.
    Send { to: usize },
    /// Allocate, receive from src, store to cache, notify finish queue.
    Receive { from: usize },
}

/// Decide the node's role for a dispatched task (Algorithm 3 lines 3–12).
pub fn node_logic(node: usize, d: &Dispatch) -> Option<NodeAction> {
    if d.task.src == node {
        Some(NodeAction::Send { to: d.task.dst })
    } else if d.task.dst == node {
        Some(NodeAction::Receive { from: d.task.src })
    } else {
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::proputil::forall;
    use crate::util::XorShiftRng;

    #[test]
    fn disjoint_pairs_dispatch_together() {
        let mut s = CentralScheduler::new();
        s.submit(0, 1, 10, 0);
        s.submit(2, 3, 10, 0);
        let d = s.tick();
        assert_eq!(d.len(), 2);
        s.check_no_conflicts().unwrap();
    }

    #[test]
    fn conflicting_pairs_serialize() {
        let mut s = CentralScheduler::new();
        let a = s.submit(0, 1, 10, 0);
        s.submit(1, 2, 10, 0); // shares endpoint 1
        let d1 = s.tick();
        assert_eq!(d1.len(), 1);
        assert_eq!(s.pending_count(), 1);
        s.notify_finish(a);
        let d2 = s.tick();
        assert_eq!(d2.len(), 1);
        assert_eq!(d2[0].task.src, 1);
    }

    #[test]
    fn finish_releases_endpoints() {
        let mut s = CentralScheduler::new();
        let id = s.submit(0, 1, 5, 0);
        s.tick();
        assert_eq!(s.inflight_count(), 1);
        s.notify_finish(id);
        s.tick();
        assert!(s.is_idle());
    }

    #[test]
    fn node_roles() {
        let d = Dispatch {
            task: TransferTask {
                id: 0,
                src: 1,
                dst: 2,
                bytes: 4,
                seq: 0,
            },
        };
        assert_eq!(node_logic(1, &d), Some(NodeAction::Send { to: 2 }));
        assert_eq!(node_logic(2, &d), Some(NodeAction::Receive { from: 1 }));
        assert_eq!(node_logic(3, &d), None);
    }

    #[test]
    fn fifo_within_eligibility() {
        let mut s = CentralScheduler::new();
        s.submit(0, 1, 1, 0);
        s.submit(0, 2, 1, 0); // blocked on 0
        s.submit(3, 4, 1, 0);
        let d = s.tick();
        let pairs: Vec<(usize, usize)> = d.iter().map(|x| (x.task.src, x.task.dst)).collect();
        assert_eq!(pairs, vec![(0, 1), (3, 4)]);
    }

    /// Property: under random submit/finish interleavings, endpoints are
    /// never double-booked and every task eventually completes.
    #[test]
    fn prop_no_double_booking_and_progress() {
        forall(
            "scheduler-conflict-freedom",
            50,
            0xC0FFEE,
            |rng: &mut XorShiftRng| {
                let n_nodes = rng.range(3, 8);
                let tasks: Vec<(usize, usize)> = (0..rng.range(5, 25))
                    .map(|_| {
                        let src = rng.below(n_nodes);
                        let mut dst = rng.below(n_nodes);
                        while dst == src {
                            dst = rng.below(n_nodes);
                        }
                        (src, dst)
                    })
                    .collect();
                (n_nodes, tasks, rng.next_u64())
            },
            |(_, tasks, seed)| {
                let mut rng = XorShiftRng::new(*seed);
                let mut s = CentralScheduler::new();
                let mut live: Vec<u64> = Vec::new();
                let mut completed = 0usize;
                let mut submitted = 0usize;
                let mut guard = 0;
                while completed < tasks.len() {
                    guard += 1;
                    if guard > 10_000 {
                        return Err("no progress".into());
                    }
                    // randomly interleave submits and finishes
                    if submitted < tasks.len() && (live.is_empty() || rng.chance(0.5)) {
                        let (src, dst) = tasks[submitted];
                        s.submit(src, dst, 8, 0);
                        submitted += 1;
                    } else if !live.is_empty() {
                        let i = rng.below(live.len());
                        let id = live.swap_remove(i);
                        s.notify_finish(id);
                        completed += 1;
                    }
                    for d in s.tick() {
                        live.push(d.task.id);
                    }
                    s.check_no_conflicts().map_err(|e| e.to_string())?;
                }
                if !s.is_idle() {
                    return Err("scheduler not idle at end".into());
                }
                Ok(())
            },
        );
    }
}
