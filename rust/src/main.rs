//! `pipedec` CLI: serve single prompts through any registered engine, drive
//! the request server, run the paper-scale cluster simulator, or inspect
//! artifacts.
//!
//! Engine selection goes through the [`pipedec::engine`] registry
//! (`EngineKind` + `build_engine`); this binary never matches on engine
//! names by hand. Flags accept both `--flag value` and `--flag=value`;
//! boolean flags need no value; unknown flags print the usage string.

use std::collections::HashMap;
use std::io::Write as _;
use std::str::FromStr;

use anyhow::{bail, Context, Result};

use pipedec::config::EngineConfig;
use pipedec::engine::{
    build_engine, build_scheduled_engine, DecodeRequest, EngineKind, NullSink, TokenSink,
};
use pipedec::server::{serve_until_idle, summarize, Router};
use pipedec::sim::{simulate_pipedec, simulate_pp, simulate_stpp, ClusterSpec, HitModel};
use pipedec::tokenizer;
use pipedec::util::XorShiftRng;
use pipedec::workload::{mixed_stream, Workload};

const USAGE: &str = "usage: pipedec <decode|serve|sim|info> [flags]

  pipedec decode  [--engine KIND] [--stages N] [--group-size G] [--width W]
                  [--children C] [--max-new N] [--prompt TEXT | --domain D]
                  [--temperature T] [--top-p P] [--top-k K] [--seed S]
                  [--threads T] [--overlap-sync BOOL] [--spec-inflight K]
                  [--config FILE]
                  [--no-prefix-cache] [--prefix-l1-bytes B] [--prefix-l2-bytes B]
                  [--prefix-l2-dir DIR] [--prefix-chunk-tokens N]
                  [--ttft-deadline S] [--deadline S] [--queue-max-wait S]
                  [--max-queue N] [--no-stream]
                  decode one prompt, streaming tokens as they are verified
                  (--no-stream prints only the final completion)
  pipedec serve   [--engine KIND] [--requests N] [--queue-cap N]
                  [engine flags as for decode]
                  submit N mixed-domain requests through the router and the
                  continuous-batching scheduler (the Fig. 8 experiment);
                  pipedec-db interleaves requests in the pipeline, every
                  other engine serves FIFO one-at-a-time
  pipedec sim     [--stages N] [--width W] [--children C] [--tokens N]
                  [--domain D]
                  paper-scale cluster simulation (70B / RTX3090)
  pipedec info    artifact + config summary

  --threads: pipeline worker threads for the pipedec engines
             (0 = auto: one per core; 1 = sequential reference path)
  --overlap-sync: overlap the sync phase's cache maintenance with the next
             timestep's compute (default true; false = serial sync)
  --spec-inflight: speculative draft generations in flight (default 1 =
             lockstep; K > 1 lets the idle draft free-run ahead, tagging
             each expansion with the commit epoch it assumed — stale ones
             are dropped at sync, outputs stay bit-identical)
  --no-prefix-cache: disable the cross-request KV prefix cache (default on;
             the PIPEDEC_NO_PREFIX_CACHE env var is an equivalent kill-switch)
  --prefix-l1-bytes / --prefix-l2-bytes: tier byte budgets for the prefix
             cache; --prefix-l2-dir enables the disk spill tier;
             --prefix-chunk-tokens sets the key granularity (0 = auto)
  --ttft-deadline / --deadline / --queue-max-wait: per-request deadlines in
             seconds (first token / total wall / admission-queue wait);
             0 = disabled. Over-deadline sessions fail, the batch continues
  --max-queue: scheduler admission-queue capacity (0 = unbounded); submits
             over capacity are shed with a typed error

  KIND (--engine): pipedec     pipeline + draft-in-pipeline dynamic-tree speculation
                   pipedec-db  SpecPipe-DB: continuous batching across requests
                   pp          plain pipeline parallelism, one token per traversal
                   stpp        static-tree pipeline speculative decoding
                   slm         draft-size model standalone on one device";

/// Flags that take no value; everything else expects one.
const BOOL_FLAGS: &[&str] = &["no-stream", "no-prefix-cache"];

/// Parse `--flag value`, `--flag=value`, and bare boolean flags into a map,
/// rejecting anything not in `allowed` with the usage string.
fn parse_flags(args: &[String], allowed: &[&str]) -> Result<HashMap<String, String>> {
    let mut out = HashMap::new();
    let mut i = 0;
    while i < args.len() {
        let a = &args[i];
        let Some(body) = a.strip_prefix("--") else {
            bail!("unexpected argument: {a}\n\n{USAGE}");
        };
        let (key, inline_val) = match body.split_once('=') {
            Some((k, v)) => (k.to_string(), Some(v.to_string())),
            None => (body.to_string(), None),
        };
        if !allowed.contains(&key.as_str()) {
            bail!("unknown flag --{key}\n\n{USAGE}");
        }
        let val = if let Some(v) = inline_val {
            i += 1;
            v
        } else if BOOL_FLAGS.contains(&key.as_str()) {
            i += 1;
            "true".to_string()
        } else {
            let v = args
                .get(i + 1)
                .with_context(|| format!("flag --{key} needs a value\n\n{USAGE}"))?;
            i += 2;
            v.clone()
        };
        out.insert(key, val);
    }
    Ok(out)
}

const ENGINE_CFG_FLAGS: &[&str] = &[
    "engine", "stages", "group-size", "width", "children", "max-new",
    "temperature", "top-p", "top-k", "seed", "threads", "overlap-sync",
    "spec-inflight", "config",
    "no-prefix-cache", "prefix-l1-bytes", "prefix-l2-bytes", "prefix-l2-dir",
    "prefix-chunk-tokens", "ttft-deadline", "deadline", "queue-max-wait",
    "max-queue",
];

fn engine_cfg(flags: &HashMap<String, String>) -> Result<EngineConfig> {
    let mut cfg = match flags.get("config") {
        Some(path) => EngineConfig::from_toml_file(std::path::Path::new(path))?,
        None => EngineConfig::default(),
    };
    if let Some(v) = flags.get("stages") {
        cfg.stages = v.parse()?;
    }
    if let Some(v) = flags.get("group-size") {
        cfg.group_size = v.parse()?;
    }
    if let Some(v) = flags.get("width") {
        cfg.tree.max_width = v.parse()?;
    }
    if let Some(v) = flags.get("children") {
        cfg.tree.max_children = v.parse()?;
    }
    if let Some(v) = flags.get("max-new") {
        cfg.max_new_tokens = v.parse()?;
    }
    if let Some(v) = flags.get("temperature") {
        cfg.temperature = v.parse()?;
    }
    if let Some(v) = flags.get("top-p") {
        cfg.top_p = v.parse()?;
    }
    if let Some(v) = flags.get("top-k") {
        cfg.top_k = v.parse()?;
    }
    if let Some(v) = flags.get("seed") {
        cfg.seed = v.parse()?;
    }
    if let Some(v) = flags.get("threads") {
        cfg.threads = v.parse()?;
    }
    if let Some(v) = flags.get("overlap-sync") {
        cfg.overlap_sync = v.parse()?;
    }
    if let Some(v) = flags.get("spec-inflight") {
        cfg.spec_inflight = v.parse()?;
    }
    if let Some(v) = flags.get("no-prefix-cache") {
        cfg.prefix_cache.enabled = !v.parse::<bool>()?;
    }
    if let Some(v) = flags.get("prefix-l1-bytes") {
        cfg.prefix_cache.l1_bytes = v.parse()?;
    }
    if let Some(v) = flags.get("prefix-l2-bytes") {
        cfg.prefix_cache.l2_bytes = v.parse()?;
    }
    if let Some(v) = flags.get("prefix-l2-dir") {
        cfg.prefix_cache.l2_dir = Some(v.clone());
    }
    if let Some(v) = flags.get("prefix-chunk-tokens") {
        cfg.prefix_cache.chunk_tokens = v.parse()?;
    }
    if let Some(v) = flags.get("ttft-deadline") {
        cfg.limits.ttft_deadline_s = v.parse()?;
    }
    if let Some(v) = flags.get("deadline") {
        cfg.limits.deadline_s = v.parse()?;
    }
    if let Some(v) = flags.get("queue-max-wait") {
        cfg.limits.queue_max_wait_s = v.parse()?;
    }
    if let Some(v) = flags.get("max-queue") {
        cfg.limits.queue_cap = v.parse()?;
    }
    cfg.validate()?;
    Ok(cfg)
}

fn engine_kind(flags: &HashMap<String, String>) -> Result<EngineKind> {
    match flags.get("engine") {
        Some(s) => EngineKind::from_str(s),
        None => Ok(EngineKind::PipeDec),
    }
}

fn pick_prompt(flags: &HashMap<String, String>) -> Result<String> {
    if let Some(p) = flags.get("prompt") {
        return Ok(p.clone());
    }
    let domain = flags.get("domain").map(|s| s.as_str()).unwrap_or("math");
    let wl = Workload::load(&pipedec::artifacts_dir(), domain)?;
    Ok(wl.prompts[0].clone())
}

/// Prints each verified token's text as soon as the engine emits it.
struct StdoutSink;

impl TokenSink for StdoutSink {
    fn on_token(&mut self, token: u32) {
        print!("{}", tokenizer::decode(&[token]));
        let _ = std::io::stdout().flush();
    }
}

fn cmd_decode(flags: HashMap<String, String>) -> Result<()> {
    let cfg = engine_cfg(&flags)?;
    let kind = engine_kind(&flags)?;
    let prompt = pick_prompt(&flags)?;
    // a bare --no-stream stores "true"; --no-stream=false re-enables
    let no_stream = flags
        .get("no-stream")
        .is_some_and(|v| !matches!(v.as_str(), "false" | "0" | "no"));
    let stream = !no_stream;
    let dir = pipedec::artifacts_dir();
    // the engines clamp the pool to groups + 1 workers; report what will
    // actually run, not the raw knob
    let workers = cfg.effective_threads().min(cfg.stages / cfg.group_size + 1);
    println!(
        "engine={kind} stages={} tree=(w={},c={}) threads={workers}",
        cfg.stages, cfg.tree.max_width, cfg.tree.max_children,
    );
    println!("--- prompt ---\n{prompt}\n--- completion ---");

    let mut engine = build_engine(kind, &dir, cfg)?;
    let req = DecodeRequest::new(&prompt);
    let r = if stream {
        let out = engine.decode(&req, &mut StdoutSink)?;
        println!(); // terminate the streamed line
        out
    } else {
        let out = engine.decode(&req, &mut NullSink)?;
        println!("{}", out.text);
        out
    };

    println!("--- stats ---");
    println!(
        "tokens={} wall={:.2}s modeled={:.3}s ({:.1} ms/token modeled)",
        r.tokens.len(),
        r.wall_s,
        r.modeled_s,
        1e3 * r.modeled_s_per_token()
    );
    if let Some(spec) = r.spec {
        println!(
            "spec: timesteps={} rounds={} hits={} misses={} accept={:.2} accepted/round={:.2}",
            spec.timesteps,
            spec.rounds,
            spec.hits,
            spec.misses,
            spec.accept_rate(),
            spec.accepted_per_round
        );
    }
    Ok(())
}

fn cmd_serve(flags: HashMap<String, String>) -> Result<()> {
    let cfg = engine_cfg(&flags)?;
    let kind = engine_kind(&flags)?;
    let n: usize = flags.get("requests").map(|s| s.parse()).transpose()?.unwrap_or(6);
    let cap: usize = flags.get("queue-cap").map(|s| s.parse()).transpose()?.unwrap_or(64);
    anyhow::ensure!(n >= 1, "--requests must be >= 1");
    let dir = pipedec::artifacts_dir();

    // worker count as the engines clamp it (groups + 1 pool ceiling)
    let threads = cfg.effective_threads().min(cfg.stages / cfg.group_size + 1);
    let mut sched = build_scheduled_engine(kind, &dir, cfg)?;
    let prompts = mixed_stream(&dir, (n + 5) / 6)?;
    let mut router = Router::new(cap);
    for p in prompts.iter().take(n) {
        router.submit_prompt(p)?;
    }
    println!(
        "serving {} queued requests through engine={kind} ({}), {threads} worker thread(s)...",
        router.depth(),
        kind.describe()
    );

    let t0 = std::time::Instant::now();
    let completions = serve_until_idle(&mut router, sched.as_mut())?;
    let wall = t0.elapsed().as_secs_f64();

    let (metrics, lat) = summarize(&completions, wall);
    println!("\nrequests:    {}", metrics.counter("requests"));
    println!("tokens:      {}", metrics.counter("tokens"));
    println!(
        "latency:     p50={:.2}s p95={:.2}s p99={:.2}s (wall, incl. queueing)",
        lat.percentile(50.0),
        lat.percentile(95.0),
        lat.percentile(99.0)
    );
    println!(
        "first token: mean={:.2}s (admission -> first streamed token)",
        metrics.summary("first_token_s").mean()
    );
    println!(
        "inter-token: mean={:.3}s (mean time between streamed tokens)",
        metrics.summary("tbt_s").mean()
    );
    println!(
        "sync phase:  decide={:.3}s commit={:.3}s overlap={:.0}% of sync on workers",
        metrics.sample_sum("t_decide_s"),
        metrics.sample_sum("t_commit_s"),
        100.0 * metrics.summary("sync_overlap_ratio").mean()
    );
    println!(
        "queue depth: mean={:.1} at admission",
        metrics.summary("queue_depth").mean()
    );
    println!(
        "throughput:  {:.1} tokens/s over {:.2}s wall",
        metrics.counter("tokens") as f64 / wall.max(1e-9),
        wall
    );
    Ok(())
}

fn cmd_sim(flags: HashMap<String, String>) -> Result<()> {
    let stages: usize = flags.get("stages").map(|s| s.parse()).transpose()?.unwrap_or(14);
    let width: usize = flags.get("width").map(|s| s.parse()).transpose()?.unwrap_or(32);
    let children: usize = flags.get("children").map(|s| s.parse()).transpose()?.unwrap_or(16);
    let tokens: usize = flags.get("tokens").map(|s| s.parse()).transpose()?.unwrap_or(512);
    let domain = flags.get("domain").map(|s| s.as_str()).unwrap_or("math");
    let cluster = ClusterSpec::paper(stages);
    let hit = HitModel::default_for(domain);
    let mut rng = XorShiftRng::new(1);
    let pd = simulate_pipedec(&cluster, width, children, &hit, tokens, &mut rng);
    let pp = simulate_pp(&cluster, tokens);
    let st = simulate_stpp(&cluster, 16, 4, 4, &hit, tokens, &mut rng);
    println!("paper-scale simulation: 70B over {stages}x RTX3090, domain={domain}");
    println!("  PipeDec-{stages}: {:8.2} ms/token (accuracy {:.2})",
        1e3 * pd.s_per_token(), pd.accuracy());
    println!("  STPP:        {:8.2} ms/token (accepted/round {:.2})",
        1e3 * st.s_per_token(), st.accepted_per_round);
    println!("  PP:          {:8.2} ms/token", 1e3 * pp.s_per_token());
    println!("  speedup vs PP:   {:.2}x", pp.s_per_token() / pd.s_per_token());
    println!("  speedup vs STPP: {:.2}x", st.s_per_token() / pd.s_per_token());
    Ok(())
}

fn cmd_info() -> Result<()> {
    let dir = pipedec::artifacts_dir();
    println!("pipedec {} — artifacts at {}", pipedec::version(), dir.display());
    for name in ["target", "draft"] {
        let cfg = pipedec::config::ArtifactConfig::load(
            &dir.join(format!("{name}_config.txt")),
        )?;
        println!(
            "  {name}: dim={} layers={} heads={} vocab={} caps(w={},tree={},past={})",
            cfg.dim, cfg.n_layers, cfg.n_heads, cfg.vocab_size,
            cfg.width_cap, cfg.tree_cap, cfg.past_cap
        );
    }
    println!("engines:");
    for kind in EngineKind::ALL {
        println!("  {:8} {}", kind.name(), kind.describe());
    }
    Ok(())
}

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let decode_flags: Vec<&str> = ENGINE_CFG_FLAGS
        .iter()
        .chain(["prompt", "domain", "no-stream"].iter())
        .copied()
        .collect();
    let serve_flags: Vec<&str> = ENGINE_CFG_FLAGS
        .iter()
        .chain(["requests", "queue-cap"].iter())
        .copied()
        .collect();
    match args.first().map(|s| s.as_str()) {
        Some("decode") => cmd_decode(parse_flags(&args[1..], &decode_flags)?),
        Some("serve") => cmd_serve(parse_flags(&args[1..], &serve_flags)?),
        Some("sim") => cmd_sim(parse_flags(
            &args[1..],
            &["stages", "width", "children", "tokens", "domain"],
        )?),
        Some("info") => cmd_info(),
        _ => {
            eprintln!("{USAGE}");
            Ok(())
        }
    }
}
