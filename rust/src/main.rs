//! `pipedec` CLI: serve single prompts through any engine, run the paper-
//! scale cluster simulator, or inspect artifacts.
//!
//! Subcommands (hand-rolled parsing; the offline vendor set has no clap):
//!   pipedec decode  [--engine pipedec|pp|stpp|slm] [--stages N] [--width W]
//!                   [--children C] [--max-new N] [--prompt TEXT|--domain D]
//!                   [--temperature T] [--config FILE]
//!   pipedec sim     [--stages N] [--width W] [--children C] [--tokens N]
//!                   [--domain D]
//!   pipedec info    # artifact + config summary

use std::collections::HashMap;

use anyhow::{bail, Context, Result};

use pipedec::baselines::{PpEngine, SlmEngine, StppEngine};
use pipedec::config::EngineConfig;
use pipedec::coordinator::PipeDecEngine;
use pipedec::sim::{simulate_pipedec, simulate_pp, simulate_stpp, ClusterSpec, HitModel};
use pipedec::util::XorShiftRng;
use pipedec::workload::Workload;

fn parse_flags(args: &[String]) -> Result<HashMap<String, String>> {
    let mut out = HashMap::new();
    let mut i = 0;
    while i < args.len() {
        let a = &args[i];
        let Some(key) = a.strip_prefix("--") else {
            bail!("unexpected argument: {a}");
        };
        let val = args.get(i + 1).context("flag needs a value")?;
        out.insert(key.to_string(), val.clone());
        i += 2;
    }
    Ok(out)
}

fn engine_cfg(flags: &HashMap<String, String>) -> Result<EngineConfig> {
    let mut cfg = match flags.get("config") {
        Some(path) => EngineConfig::from_toml_file(std::path::Path::new(path))?,
        None => EngineConfig::default(),
    };
    if let Some(v) = flags.get("stages") {
        cfg.stages = v.parse()?;
    }
    if let Some(v) = flags.get("width") {
        cfg.tree.max_width = v.parse()?;
    }
    if let Some(v) = flags.get("children") {
        cfg.tree.max_children = v.parse()?;
    }
    if let Some(v) = flags.get("max-new") {
        cfg.max_new_tokens = v.parse()?;
    }
    if let Some(v) = flags.get("temperature") {
        cfg.temperature = v.parse()?;
    }
    if let Some(v) = flags.get("seed") {
        cfg.seed = v.parse()?;
    }
    cfg.validate()?;
    Ok(cfg)
}

fn pick_prompt(flags: &HashMap<String, String>) -> Result<String> {
    if let Some(p) = flags.get("prompt") {
        return Ok(p.clone());
    }
    let domain = flags.get("domain").map(|s| s.as_str()).unwrap_or("math");
    let wl = Workload::load(&pipedec::artifacts_dir(), domain)?;
    Ok(wl.prompts[0].clone())
}

fn cmd_decode(flags: HashMap<String, String>) -> Result<()> {
    let cfg = engine_cfg(&flags)?;
    let prompt = pick_prompt(&flags)?;
    let dir = pipedec::artifacts_dir();
    let engine = flags.get("engine").map(|s| s.as_str()).unwrap_or("pipedec");
    println!("engine={engine} stages={} tree=(w={},c={})", cfg.stages,
        cfg.tree.max_width, cfg.tree.max_children);
    println!("--- prompt ---\n{prompt}\n--- completion ---");
    match engine {
        "pipedec" => {
            let mut e = PipeDecEngine::new(&dir, cfg)?;
            let r = e.decode(&prompt)?;
            println!("{}", r.text);
            println!(
                "--- stats ---\ntokens={} timesteps={} hits={} misses={} accept={:.2}",
                r.tokens.len(), r.timesteps, r.hits, r.misses, r.accept_rate()
            );
            println!(
                "wall={:.2}s modeled={:.3}s ({:.1} ms/token modeled)",
                r.wall_s, r.modeled_s, 1e3 * r.modeled_s_per_token()
            );
        }
        "pp" => {
            let r = PpEngine::new(&dir, cfg)?.decode(&prompt)?;
            println!("{}", r.text);
            println!("--- stats ---\ntokens={} wall={:.2}s modeled={:.3}s",
                r.tokens.len(), r.wall_s, r.modeled_s);
        }
        "stpp" => {
            let r = StppEngine::new(&dir, cfg)?.decode(&prompt)?;
            println!("{}", r.text);
            println!("--- stats ---\ntokens={} accepted/round={:.2} modeled={:.3}s",
                r.tokens.len(), r.accepted_per_round, r.modeled_s);
        }
        "slm" => {
            let r = SlmEngine::new(&dir, cfg)?.decode(&prompt)?;
            println!("{}", r.text);
            println!("--- stats ---\ntokens={} wall={:.2}s", r.tokens.len(), r.wall_s);
        }
        other => bail!("unknown engine {other}"),
    }
    Ok(())
}

fn cmd_sim(flags: HashMap<String, String>) -> Result<()> {
    let stages: usize = flags.get("stages").map(|s| s.parse()).transpose()?.unwrap_or(14);
    let width: usize = flags.get("width").map(|s| s.parse()).transpose()?.unwrap_or(32);
    let children: usize = flags.get("children").map(|s| s.parse()).transpose()?.unwrap_or(16);
    let tokens: usize = flags.get("tokens").map(|s| s.parse()).transpose()?.unwrap_or(512);
    let domain = flags.get("domain").map(|s| s.as_str()).unwrap_or("math");
    let cluster = ClusterSpec::paper(stages);
    let hit = HitModel::default_for(domain);
    let mut rng = XorShiftRng::new(1);
    let pd = simulate_pipedec(&cluster, width, children, &hit, tokens, &mut rng);
    let pp = simulate_pp(&cluster, tokens);
    let st = simulate_stpp(&cluster, 16, 4, 4, &hit, tokens, &mut rng);
    println!("paper-scale simulation: 70B over {stages}x RTX3090, domain={domain}");
    println!("  PipeDec-{stages}: {:8.2} ms/token (accuracy {:.2})",
        1e3 * pd.s_per_token(), pd.accuracy());
    println!("  STPP:        {:8.2} ms/token (accepted/round {:.2})",
        1e3 * st.s_per_token(), st.accepted_per_round);
    println!("  PP:          {:8.2} ms/token", 1e3 * pp.s_per_token());
    println!("  speedup vs PP:   {:.2}x", pp.s_per_token() / pd.s_per_token());
    println!("  speedup vs STPP: {:.2}x", st.s_per_token() / pd.s_per_token());
    Ok(())
}

fn cmd_info() -> Result<()> {
    let dir = pipedec::artifacts_dir();
    println!("pipedec {} — artifacts at {}", pipedec::version(), dir.display());
    for name in ["target", "draft"] {
        let cfg = pipedec::config::ArtifactConfig::load(
            &dir.join(format!("{name}_config.txt")),
        )?;
        println!(
            "  {name}: dim={} layers={} heads={} vocab={} caps(w={},tree={},past={})",
            cfg.dim, cfg.n_layers, cfg.n_heads, cfg.vocab_size,
            cfg.width_cap, cfg.tree_cap, cfg.past_cap
        );
    }
    Ok(())
}

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    match args.first().map(|s| s.as_str()) {
        Some("decode") => cmd_decode(parse_flags(&args[1..])?),
        Some("sim") => cmd_sim(parse_flags(&args[1..])?),
        Some("info") => cmd_info(),
        _ => {
            eprintln!("usage: pipedec <decode|sim|info> [flags]  (see rust/src/main.rs)");
            Ok(())
        }
    }
}
