//! Small shared utilities: deterministic RNG, top-k selection, statistics.

pub mod rng;
pub mod stats;
pub mod topk;

pub use rng::XorShiftRng;
pub use stats::Summary;
pub use topk::{top_k_indices, top_k_weighted};

/// Numerically safe log for probabilities (clamps at a tiny epsilon so the
/// cumulative log-probability algebra of §3.3.3 never sees -inf).
#[inline]
pub fn safe_ln(p: f32) -> f32 {
    p.max(1e-30).ln()
}

/// log-sum-exp over a slice (used by sampling and by tests).
pub fn log_sum_exp(xs: &[f32]) -> f32 {
    let m = xs.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    if !m.is_finite() {
        return m;
    }
    m + xs.iter().map(|x| (x - m).exp()).sum::<f32>().ln()
}

/// Softmax in place.
pub fn softmax_inplace(xs: &mut [f32]) {
    let lse = log_sum_exp(xs);
    for x in xs.iter_mut() {
        *x = (*x - lse).exp();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lse_matches_naive() {
        let xs = [0.5f32, -1.0, 2.0, 0.0];
        let naive = xs.iter().map(|x| x.exp()).sum::<f32>().ln();
        assert!((log_sum_exp(&xs) - naive).abs() < 1e-6);
    }

    #[test]
    fn softmax_sums_to_one() {
        let mut xs = [1.0f32, 2.0, 3.0, -5.0];
        softmax_inplace(&mut xs);
        let s: f32 = xs.iter().sum();
        assert!((s - 1.0).abs() < 1e-6);
        assert!(xs.iter().all(|&x| x > 0.0));
    }

    #[test]
    fn safe_ln_no_neg_inf() {
        assert!(safe_ln(0.0).is_finite());
        assert!((safe_ln(1.0) - 0.0).abs() < 1e-9);
    }
}
