//! Deterministic xorshift64* PRNG.
//!
//! Every stochastic decision in the crate (stochastic decoding, workload
//! sampling, simulator jitter, property-test generation) goes through this
//! generator so experiments are exactly reproducible from a seed.

#[derive(Debug, Clone)]
pub struct XorShiftRng {
    state: u64,
}

impl XorShiftRng {
    pub fn new(seed: u64) -> Self {
        // avoid the all-zero fixed point
        Self {
            state: seed.wrapping_mul(0x9E3779B97F4A7C15).max(1),
        }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let mut x = self.state;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.state = x;
        x.wrapping_mul(0x2545F4914F6CDD1D)
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 / (1u64 << 24) as f32
    }

    /// Uniform in [0, 1) with f64 precision.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Uniform integer in [0, n).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.next_u64() % n as u64) as usize
    }

    /// Uniform integer in [lo, hi).
    #[inline]
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.below(hi - lo)
    }

    /// Bernoulli trial.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }

    /// Sample an index from unnormalized non-negative weights.
    pub fn weighted(&mut self, weights: &[f32]) -> usize {
        let total: f32 = weights.iter().sum();
        if total <= 0.0 {
            return self.below(weights.len().max(1));
        }
        let mut t = self.next_f32() * total;
        for (i, &w) in weights.iter().enumerate() {
            t -= w;
            if t <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Fork a stream for a sub-component; deterministic in (self, tag).
    pub fn fork(&mut self, tag: u64) -> XorShiftRng {
        XorShiftRng::new(self.next_u64() ^ tag.wrapping_mul(0xA24BAED4963EE407))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = XorShiftRng::new(7);
        let mut b = XorShiftRng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn f32_in_unit_interval() {
        let mut r = XorShiftRng::new(3);
        for _ in 0..10_000 {
            let x = r.next_f32();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_is_bounded_and_covers() {
        let mut r = XorShiftRng::new(11);
        let mut seen = [false; 7];
        for _ in 0..1_000 {
            seen[r.below(7)] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn weighted_prefers_heavy() {
        let mut r = XorShiftRng::new(5);
        let w = [0.01f32, 0.01, 0.98];
        let mut hits = 0;
        for _ in 0..1_000 {
            if r.weighted(&w) == 2 {
                hits += 1;
            }
        }
        assert!(hits > 900, "hits={hits}");
    }

    #[test]
    fn mean_close_to_half() {
        let mut r = XorShiftRng::new(9);
        let n = 50_000;
        let s: f64 = (0..n).map(|_| r.next_f64()).sum();
        assert!(((s / n as f64) - 0.5).abs() < 0.01);
    }
}
