//! Top-k selection helpers used by tree expansion (§3.3.3 "Tree Layer
//! Generation") and by decoding (top-k sampling).

/// Indices of the `k` largest values, in descending value order.
/// Ties break toward the lower index (stable, matches jnp.top_k).
pub fn top_k_indices(values: &[f32], k: usize) -> Vec<usize> {
    let k = k.min(values.len());
    let mut idx: Vec<usize> = (0..values.len()).collect();
    // partial selection: O(n log k) via a simple sort on the slice is fine at
    // our sizes (n <= width*children = 2048); keep it simple and stable.
    idx.sort_by(|&a, &b| {
        values[b]
            .partial_cmp(&values[a])
            .unwrap_or(std::cmp::Ordering::Equal)
            .then(a.cmp(&b))
    });
    idx.truncate(k);
    idx
}

/// (index, value) pairs of the k largest entries, descending.
pub fn top_k_weighted(values: &[f32], k: usize) -> Vec<(usize, f32)> {
    top_k_indices(values, k)
        .into_iter()
        .map(|i| (i, values[i]))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn picks_largest() {
        let v = [0.1f32, 5.0, -2.0, 3.0, 3.0];
        assert_eq!(top_k_indices(&v, 3), vec![1, 3, 4]);
    }

    #[test]
    fn k_larger_than_len() {
        let v = [1.0f32, 2.0];
        assert_eq!(top_k_indices(&v, 10), vec![1, 0]);
    }

    #[test]
    fn stable_on_ties() {
        let v = [1.0f32, 1.0, 1.0];
        assert_eq!(top_k_indices(&v, 2), vec![0, 1]);
    }

    #[test]
    fn weighted_pairs() {
        let v = [0.2f32, 0.8];
        assert_eq!(top_k_weighted(&v, 1), vec![(1, 0.8)]);
    }
}
