//! Summary statistics for latency/throughput reporting (mean, percentiles).

#[derive(Debug, Clone, Default)]
pub struct Summary {
    sorted: Vec<f64>,
    sum: f64,
}

impl Summary {
    pub fn from_samples(mut samples: Vec<f64>) -> Self {
        samples.retain(|x| x.is_finite());
        samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let sum = samples.iter().sum();
        Self {
            sorted: samples,
            sum,
        }
    }

    pub fn len(&self) -> usize {
        self.sorted.len()
    }

    pub fn is_empty(&self) -> bool {
        self.sorted.is_empty()
    }

    pub fn mean(&self) -> f64 {
        if self.sorted.is_empty() {
            0.0
        } else {
            self.sum / self.sorted.len() as f64
        }
    }

    /// Percentile in [0, 100], nearest-rank with linear interpolation.
    pub fn percentile(&self, p: f64) -> f64 {
        if self.sorted.is_empty() {
            return 0.0;
        }
        let n = self.sorted.len();
        let rank = (p / 100.0) * (n - 1) as f64;
        let lo = rank.floor() as usize;
        let hi = rank.ceil() as usize;
        if lo == hi {
            self.sorted[lo]
        } else {
            let f = rank - lo as f64;
            self.sorted[lo] * (1.0 - f) + self.sorted[hi] * f
        }
    }

    pub fn min(&self) -> f64 {
        self.sorted.first().copied().unwrap_or(0.0)
    }

    pub fn max(&self) -> f64 {
        self.sorted.last().copied().unwrap_or(0.0)
    }

    pub fn std_dev(&self) -> f64 {
        if self.sorted.len() < 2 {
            return 0.0;
        }
        let m = self.mean();
        let var = self
            .sorted
            .iter()
            .map(|x| (x - m) * (x - m))
            .sum::<f64>()
            / (self.sorted.len() - 1) as f64;
        var.sqrt()
    }
}

impl std::fmt::Display for Summary {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "n={} mean={:.3} p50={:.3} p95={:.3} p99={:.3} max={:.3}",
            self.len(),
            self.mean(),
            self.percentile(50.0),
            self.percentile(95.0),
            self.percentile(99.0),
            self.max()
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basic_stats() {
        let s = Summary::from_samples(vec![1.0, 2.0, 3.0, 4.0, 5.0]);
        assert!((s.mean() - 3.0).abs() < 1e-9);
        assert!((s.percentile(50.0) - 3.0).abs() < 1e-9);
        assert!((s.min() - 1.0).abs() < 1e-9);
        assert!((s.max() - 5.0).abs() < 1e-9);
    }

    #[test]
    fn percentile_interpolates() {
        let s = Summary::from_samples(vec![0.0, 10.0]);
        assert!((s.percentile(25.0) - 2.5).abs() < 1e-9);
    }

    #[test]
    fn empty_is_safe() {
        let s = Summary::from_samples(vec![]);
        assert_eq!(s.mean(), 0.0);
        assert_eq!(s.percentile(99.0), 0.0);
    }

    #[test]
    fn nan_filtered() {
        let s = Summary::from_samples(vec![1.0, f64::NAN, 3.0]);
        assert_eq!(s.len(), 2);
    }
}
