"""L2 correctness: the exported entry points compose to the training-path
forward; bias helpers; stage splitting."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.configs import DRAFT, TARGET
from compile.model import (
    LAYER_WEIGHT_ORDER, causal_block_bias, embed_step, forward_train,
    head_step, init_params, layer_step, loss_fn, past_bias_for,
)


@pytest.fixture(scope="module")
def draft_params():
    return init_params(DRAFT, jax.random.PRNGKey(0))


def run_prefill_via_layer_step(params, cfg, seq, P=64, T=32, use_kernel=True):
    S = len(seq)
    H, hd = cfg.n_heads, cfg.head_dim
    h = embed_step(params["emb"], jnp.asarray(seq, jnp.int32))[0]
    pos = jnp.arange(S, dtype=jnp.int32)
    pb = past_bias_for(0, S, P)
    tb = causal_block_bias(S, 0, S, T)
    for lp in params["layers"]:
        args = [lp[n] for n in LAYER_WEIGHT_ORDER]
        h, _, _ = layer_step(
            *args, h, jnp.zeros((H, P, hd)), jnp.zeros((H, P, hd)),
            jnp.zeros((H, T, hd)), jnp.zeros((H, T, hd)),
            jnp.int32(0), pos, pb, tb, cfg=cfg, use_kernel=use_kernel)
    return head_step(params["final_norm"], params["emb"], h, cfg.norm_eps)[0]


def test_layer_step_composes_to_forward_train(draft_params):
    seq = list(np.random.default_rng(0).integers(4, 90, 12))
    logits = run_prefill_via_layer_step(draft_params, DRAFT, seq)
    ref = forward_train(draft_params, jnp.asarray([seq], jnp.int32), DRAFT)[0]
    np.testing.assert_allclose(np.asarray(logits), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


def test_kernel_and_ref_paths_agree(draft_params):
    seq = list(np.random.default_rng(1).integers(4, 90, 8))
    a = run_prefill_via_layer_step(draft_params, DRAFT, seq, use_kernel=True)
    b = run_prefill_via_layer_step(draft_params, DRAFT, seq, use_kernel=False)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-5)


def test_incremental_decode_matches_full_context(draft_params):
    """Two-level cache semantics: prefill N then decode 1 via tree block ==
    full forward over N+1."""
    cfg = DRAFT
    rng = np.random.default_rng(2)
    seq = list(rng.integers(4, 90, 9))
    P, T = 64, 16
    H, hd = cfg.n_heads, cfg.head_dim
    # prefill first 8, capture past kv
    h = embed_step(draft_params["emb"], jnp.asarray(seq[:8], jnp.int32))[0]
    pos = jnp.arange(8, dtype=jnp.int32)
    pb = past_bias_for(0, 8, P)
    tb = causal_block_bias(8, 0, 8, T)
    past = []
    for lp in draft_params["layers"]:
        args = [lp[n] for n in LAYER_WEIGHT_ORDER]
        h, k_new, v_new = layer_step(
            *args, h, jnp.zeros((H, P, hd)), jnp.zeros((H, P, hd)),
            jnp.zeros((H, T, hd)), jnp.zeros((H, T, hd)),
            jnp.int32(0), pos, pb, tb, cfg=cfg)
        pk = jnp.zeros((H, P, hd)).at[:, :8].set(k_new[:, :8])
        pv = jnp.zeros((H, P, hd)).at[:, :8].set(v_new[:, :8])
        past.append((pk, pv))
    # decode token 9 as a width-1 tree block
    h = embed_step(draft_params["emb"], jnp.asarray([seq[8]], jnp.int32))[0][:1]
    pos1 = jnp.asarray([8], jnp.int32)
    pb1 = past_bias_for(8, 1, P)
    tb1 = causal_block_bias(1, 0, 1, T)
    for lp, (pk, pv) in zip(draft_params["layers"], past):
        args = [lp[n] for n in LAYER_WEIGHT_ORDER]
        h, _, _ = layer_step(
            *args, h, pk, pv,
            jnp.zeros((H, T, hd)), jnp.zeros((H, T, hd)),
            jnp.int32(0), pos1, pb1, tb1, cfg=cfg)
    logits = head_step(draft_params["final_norm"], draft_params["emb"], h,
                       cfg.norm_eps)[0][0]
    ref = forward_train(draft_params, jnp.asarray([seq], jnp.int32), cfg)[0, -1]
    np.testing.assert_allclose(np.asarray(logits), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


def test_bias_shapes():
    assert past_bias_for(3, 4, 8).shape == (4, 8)
    assert causal_block_bias(2, 1, 4, 8).shape == (4, 8)


def test_loss_decreases_on_tiny_batch(draft_params):
    """One gradient step on a repeated batch reduces loss."""
    toks = jnp.asarray(np.random.default_rng(3).integers(4, 90, (2, 24)),
                       jnp.int32)
    l0, g = jax.value_and_grad(loss_fn)(draft_params, toks, DRAFT)
    p1 = jax.tree_util.tree_map(lambda p, gg: p - 0.05 * gg, draft_params, g)
    l1 = loss_fn(p1, toks, DRAFT)
    assert float(l1) < float(l0)


def test_param_counts_match_config():
    for cfg in (TARGET, DRAFT):
        params = init_params(cfg, jax.random.PRNGKey(1))
        n = sum(int(np.prod(x.shape)) for x in jax.tree_util.tree_leaves(params))
        assert n == cfg.param_count()
