"""L1 correctness: the Pallas dynamic tree attention kernel vs the pure-jnp
oracle, swept over shapes/dtypes with hypothesis — the CORE correctness
signal for the compute hot-spot."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.ref import tree_attention_ref_mha
from compile.kernels.tree_attention import tree_attention, vmem_estimate_bytes

NEG = -1e9


def rand_inputs(rng, h, w, p, t, hd, past_valid, tree_mode):
    q = rng.standard_normal((h, w, hd)).astype(np.float32)
    pk = rng.standard_normal((h, p, hd)).astype(np.float32)
    pv = rng.standard_normal((h, p, hd)).astype(np.float32)
    tk = rng.standard_normal((h, t, hd)).astype(np.float32)
    tv = rng.standard_normal((h, t, hd)).astype(np.float32)
    pb = np.full((w, p), NEG, np.float32)
    pb[:, :past_valid] = 0.0
    tb = np.full((w, t), NEG, np.float32)
    if tree_mode == "causal":
        for i in range(w):
            tb[i, : i + 1] = 0.0
    elif tree_mode == "random":
        mask = rng.random((w, t)) < 0.4
        mask[:, 0] = True  # at least one valid column per row
        tb[mask] = 0.0
    else:  # self-only
        for i in range(min(w, t)):
            tb[i, i] = 0.0
        tb[:, 0] = 0.0
    return q, pk, pv, tk, tv, pb, tb


@pytest.mark.parametrize("past_valid", [0, 1, 7, 16])
@pytest.mark.parametrize("tree_mode", ["causal", "random", "self"])
def test_kernel_matches_oracle_basic(past_valid, tree_mode):
    rng = np.random.default_rng(42)
    args = rand_inputs(rng, 2, 8, 16, 12, 32, past_valid, tree_mode)
    out = np.asarray(tree_attention(*args))
    ref = np.asarray(tree_attention_ref_mha(*args))
    np.testing.assert_allclose(out, ref, rtol=1e-5, atol=1e-5)


@settings(max_examples=25, deadline=None)
@given(
    h=st.sampled_from([1, 2, 4]),
    w=st.sampled_from([1, 4, 8, 32]),
    p=st.sampled_from([8, 64, 512]),
    t=st.sampled_from([8, 32, 288]),
    hd=st.sampled_from([8, 32]),
    past_frac=st.floats(0.0, 1.0),
    seed=st.integers(0, 2**16),
)
def test_kernel_matches_oracle_hypothesis(h, w, p, t, hd, past_frac, seed):
    rng = np.random.default_rng(seed)
    past_valid = int(past_frac * p)
    args = rand_inputs(rng, h, w, p, t, hd, past_valid, "random")
    out = np.asarray(tree_attention(*args))
    ref = np.asarray(tree_attention_ref_mha(*args))
    assert np.isfinite(out).all()
    np.testing.assert_allclose(out, ref, rtol=2e-5, atol=2e-5)


def test_kernel_rows_are_convex_combinations():
    """With softmax weights, each output row lies in the convex hull of the
    value vectors -> norm bounded by the max value norm."""
    rng = np.random.default_rng(7)
    args = rand_inputs(rng, 2, 8, 32, 16, 16, 8, "causal")
    out = np.asarray(tree_attention(*args))
    vmax = max(np.abs(args[2]).max(), np.abs(args[4]).max())
    assert np.abs(out).max() <= vmax + 1e-5


def test_kernel_ignores_masked_tree_values():
    """Fully-masked tree columns must not influence the output."""
    rng = np.random.default_rng(3)
    a1 = rand_inputs(rng, 1, 4, 8, 8, 8, 4, "causal")
    a2 = list(a1)
    tk, tv, tb = a2[3].copy(), a2[4].copy(), a2[6]
    masked_cols = np.all(tb == NEG, axis=0)
    tk[:, masked_cols] = 999.0
    tv[:, masked_cols] = -999.0
    a2[3], a2[4] = tk, tv
    np.testing.assert_allclose(
        np.asarray(tree_attention(*a1)), np.asarray(tree_attention(*a2)),
        rtol=1e-6, atol=1e-6)


def test_kernel_inside_jit():
    rng = np.random.default_rng(5)
    args = rand_inputs(rng, 2, 8, 16, 12, 32, 4, "causal")
    f = jax.jit(tree_attention)
    out = np.asarray(f(*args))
    ref = np.asarray(tree_attention_ref_mha(*args))
    np.testing.assert_allclose(out, ref, rtol=1e-5, atol=1e-5)


def test_vmem_estimate_within_budget():
    """DESIGN §Hardware-Adaptation: per-instance tiles fit VMEM (16 MiB)."""
    b = vmem_estimate_bytes(w=128, p=512, t=288, hd=64)
    assert b < 16 * 1024 * 1024
