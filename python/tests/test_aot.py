"""AOT path: HLO text emission, pdw roundtrip, tokenizer parity, corpus
determinism."""

import os

import jax
import jax.numpy as jnp
import numpy as np

from compile import corpus, tokenizer
from compile.aot import lower_embed, lower_head, lower_layer, to_hlo_text
from compile.configs import DRAFT, VOCAB_SIZE, config_lines
from compile.pdw import flatten_params, read_pdw, unflatten_params, write_pdw
from compile.model import init_params


def test_hlo_text_emits_and_mentions_entry(tmp_path):
    text = to_hlo_text(lower_embed(DRAFT))
    assert "ENTRY" in text
    assert len(text) > 200


def test_layer_lowering_has_expected_arity():
    text = to_hlo_text(lower_layer(DRAFT))
    # 9 weights + 9 runtime args
    assert "ENTRY" in text
    assert text.count("parameter(") >= 18


def test_head_lowering():
    assert "ENTRY" in to_hlo_text(lower_head(DRAFT))


def test_pdw_roundtrip(tmp_path):
    params = init_params(DRAFT, jax.random.PRNGKey(0))
    flat = flatten_params(jax.device_get(params))
    path = os.path.join(tmp_path, "w.pdw")
    write_pdw(path, flat)
    back = read_pdw(path)
    assert set(back) == set(flat)
    for k in flat:
        np.testing.assert_array_equal(back[k], np.asarray(flat[k], np.float32))
    re = unflatten_params(back, DRAFT.n_layers)
    assert len(re["layers"]) == DRAFT.n_layers


def test_tokenizer_roundtrip_and_vocab():
    text = "hello World 42!\n<math> x*y"
    ids = tokenizer.encode(text)
    assert tokenizer.decode(ids) == text
    assert max(ids) < VOCAB_SIZE


def test_corpus_is_deterministic_and_covers_domains():
    a = corpus.build_corpus(seed=7, samples_per_domain=5)
    b = corpus.build_corpus(seed=7, samples_per_domain=5)
    assert a == b
    for d in corpus.DOMAINS:
        assert f"<{d}>" in a


def test_domain_prompts_are_prefixes():
    for d in corpus.DOMAINS:
        ps = corpus.domain_prompts(d, 3)
        assert len(ps) == 3
        assert all(p.startswith(f"<{d}>") for p in ps)


def test_config_lines_parse_back():
    lines = config_lines(DRAFT)
    kv = dict(l.split("=") for l in lines.strip().split("\n"))
    assert int(kv["dim"]) == DRAFT.dim
    assert int(kv["n_layers"]) == DRAFT.n_layers
