"""AOT path: HLO text emission, pdw roundtrip, tokenizer parity, corpus
determinism."""

import os

import jax
import jax.numpy as jnp
import numpy as np

from compile import corpus, tokenizer
from compile.aot import emit, lower_embed, lower_head, lower_layer, to_hlo_text
from compile.configs import DRAFT, PAST_CAP, TREE_CAP, VOCAB_SIZE, config_lines
from compile.kvops import (
    kv_append, kv_gather, kv_promote,
    lower_kv_append, lower_kv_gather, lower_kv_promote,
)
from compile.pdw import flatten_params, read_pdw, unflatten_params, write_pdw
from compile.model import init_params


def test_hlo_text_emits_and_mentions_entry(tmp_path):
    text = to_hlo_text(lower_embed(DRAFT))
    assert "ENTRY" in text
    assert len(text) > 200


def test_layer_lowering_has_expected_arity():
    text = to_hlo_text(lower_layer(DRAFT))
    # 9 weights + 9 runtime args
    assert "ENTRY" in text
    assert text.count("parameter(") >= 18


def test_head_lowering():
    assert "ENTRY" in to_hlo_text(lower_head(DRAFT))


def test_pdw_roundtrip(tmp_path):
    params = init_params(DRAFT, jax.random.PRNGKey(0))
    flat = flatten_params(jax.device_get(params))
    path = os.path.join(tmp_path, "w.pdw")
    write_pdw(path, flat)
    back = read_pdw(path)
    assert set(back) == set(flat)
    for k in flat:
        np.testing.assert_array_equal(back[k], np.asarray(flat[k], np.float32))
    re = unflatten_params(back, DRAFT.n_layers)
    assert len(re["layers"]) == DRAFT.n_layers


def test_tokenizer_roundtrip_and_vocab():
    text = "hello World 42!\n<math> x*y"
    ids = tokenizer.encode(text)
    assert tokenizer.decode(ids) == text
    assert max(ids) < VOCAB_SIZE


def test_corpus_is_deterministic_and_covers_domains():
    a = corpus.build_corpus(seed=7, samples_per_domain=5)
    b = corpus.build_corpus(seed=7, samples_per_domain=5)
    assert a == b
    for d in corpus.DOMAINS:
        assert f"<{d}>" in a


def test_domain_prompts_are_prefixes():
    for d in corpus.DOMAINS:
        ps = corpus.domain_prompts(d, 3)
        assert len(ps) == 3
        assert all(p.startswith(f"<{d}>") for p in ps)


def test_kv_entry_points_emit_with_donation_and_manifest(tmp_path):
    """The kv update lowerings must (a) land in the manifest under their
    artifact names and (b) carry the input->output donation annotation in
    the emitted HLO text — without it the runtime's in-place mirror update
    would silently copy instead of aliasing."""
    manifest = []
    emit(str(tmp_path), "draft_kvapp_past_w8",
         lower_kv_append(DRAFT, PAST_CAP, 8), manifest, return_tuple=False)
    emit(str(tmp_path), "draft_kvapp_tree_w8",
         lower_kv_append(DRAFT, TREE_CAP, 8), manifest, return_tuple=False)
    emit(str(tmp_path), "draft_kvprom",
         lower_kv_promote(DRAFT), manifest, return_tuple=False)
    emit(str(tmp_path), "draft_kvcompact",
         lower_kv_gather(DRAFT), manifest, return_tuple=False)
    names = [m.split()[0] for m in manifest]
    assert names == [
        "draft_kvapp_past_w8.hlo.txt",
        "draft_kvapp_tree_w8.hlo.txt",
        "draft_kvprom.hlo.txt",
        "draft_kvcompact.hlo.txt",
    ]
    for name in names:
        text = (tmp_path / name).read_text()
        assert "ENTRY" in text
        assert "input_output_alias" in text, f"{name}: donation lost"


def test_kv_lowering_untupled_single_output():
    # an untupled root is what lets the output buffer alias the donated
    # argument; a tuple root would need a host-side decompose
    text = to_hlo_text(lower_kv_append(DRAFT, TREE_CAP, 8), return_tuple=False)
    entry = text.split("ENTRY", 1)[1]
    assert entry.count("parameter(") == 4
    assert "input_output_alias={ {}: (0, {}, may-alias) }" in text


def test_kv_append_matches_rebuild():
    """Golden parity: appending a block in place must equal rebuilding the
    level tensor from scratch (the host cache's copy_block semantics),
    including interior starts, the capacity boundary, and count=0."""
    rng = np.random.default_rng(0)
    nh, hd, w = DRAFT.n_heads, DRAFT.head_dim, 8
    dst = rng.standard_normal((nh, TREE_CAP, hd)).astype(np.float32)
    src = rng.standard_normal((nh, w, hd)).astype(np.float32)
    fn = jax.jit(kv_append)
    for start, count in [(0, w), (5, 3), (TREE_CAP - 2, 2), (7, 0)]:
        ref = dst.copy()
        ref[:, start:start + count, :] = src[:, :count, :]
        out = np.asarray(fn(dst, src, start, count))
        np.testing.assert_array_equal(out, ref)


def test_kv_promote_and_gather_match_host_semantics():
    rng = np.random.default_rng(1)
    nh, hd = DRAFT.n_heads, DRAFT.head_dim
    past = rng.standard_normal((nh, PAST_CAP, hd)).astype(np.float32)
    tree = rng.standard_normal((nh, TREE_CAP, hd)).astype(np.float32)
    # promote: tree slot 2 -> past row 7, everything else untouched
    out = np.asarray(jax.jit(kv_promote)(past, tree, 2, 7))
    ref = past.copy()
    ref[:, 7, :] = tree[:, 2, :]
    np.testing.assert_array_equal(out, ref)
    # gather-compact: keep prefix moves, identity suffix leaves rows
    # bit-identical to the host's in-place compaction (which never
    # touches rows past the keep length)
    keep = [1, 3, 4]
    idx = np.arange(TREE_CAP, dtype=np.int32)
    idx[: len(keep)] = keep
    out = np.asarray(jax.jit(kv_gather)(tree, idx))
    ref = tree.copy()
    for new, old in enumerate(keep):
        ref[:, new, :] = tree[:, old, :]
    np.testing.assert_array_equal(out, ref)


def test_config_lines_parse_back():
    lines = config_lines(DRAFT)
    kv = dict(l.split("=") for l in lines.strip().split("\n"))
    assert int(kv["dim"]) == DRAFT.dim
    assert int(kv["n_layers"]) == DRAFT.n_layers
