"""Build-time training of the target and draft models on the 6-domain corpus.

This runs exactly once, inside ``make artifacts`` — never at serve time. Both
models are trained on the same token stream so the draft acquires the
substantial top-k agreement with the target that speculative decoding needs
(the paper gets this for free from the LLaMA family; we get it from
co-training — DESIGN.md §Model scale substitution).
"""

import time

import jax
import jax.numpy as jnp
import numpy as np

from . import corpus, tokenizer
from .configs import DRAFT, TARGET, TRAIN, ModelConfig, TrainConfig
from .model import init_params, loss_fn
from .pdw import flatten_params, write_pdw


def token_stream(seed: int = 7) -> np.ndarray:
    text = corpus.build_corpus(seed=seed)
    return np.asarray(tokenizer.encode(text), dtype=np.int32)


def sample_batch(stream: np.ndarray, rng: np.random.Generator,
                 batch: int, seq: int) -> np.ndarray:
    starts = rng.integers(0, len(stream) - seq - 1, size=batch)
    return np.stack([stream[s : s + seq + 1] for s in starts])


def adam_init(params):
    zeros = jax.tree_util.tree_map(jnp.zeros_like, params)
    return {"m": zeros, "v": jax.tree_util.tree_map(jnp.zeros_like, params), "t": 0}


def make_update(cfg: ModelConfig, tc: TrainConfig):
    @jax.jit
    def update(params, opt, tokens, lr):
        loss, grads = jax.value_and_grad(loss_fn)(params, tokens, cfg)
        # global-norm clip
        leaves = jax.tree_util.tree_leaves(grads)
        gnorm = jnp.sqrt(sum(jnp.sum(g * g) for g in leaves))
        scale = jnp.minimum(1.0, tc.grad_clip / (gnorm + 1e-9))
        grads = jax.tree_util.tree_map(lambda g: g * scale, grads)

        t = opt["t"] + 1
        b1, b2, eps = 0.9, 0.95, 1e-8
        m = jax.tree_util.tree_map(
            lambda mm, g: b1 * mm + (1 - b1) * g, opt["m"], grads)
        v = jax.tree_util.tree_map(
            lambda vv, g: b2 * vv + (1 - b2) * g * g, opt["v"], grads)
        mhat = jax.tree_util.tree_map(lambda mm: mm / (1 - b1 ** t), m)
        vhat = jax.tree_util.tree_map(lambda vv: vv / (1 - b2 ** t), v)
        params = jax.tree_util.tree_map(
            lambda p, mm, vv: p - lr * (mm / (jnp.sqrt(vv) + eps)
                                        + tc.weight_decay * p),
            params, mhat, vhat)
        return params, {"m": m, "v": v, "t": t}, loss

    return update


def lr_at(step: int, tc: TrainConfig) -> float:
    if step < tc.warmup:
        return tc.lr * (step + 1) / tc.warmup
    frac = (step - tc.warmup) / max(1, tc.steps - tc.warmup)
    return tc.lr * 0.5 * (1.0 + float(np.cos(np.pi * frac)))


def train_model(cfg: ModelConfig, tc: TrainConfig, stream: np.ndarray,
                log=print) -> tuple[dict, list[float]]:
    key = jax.random.PRNGKey(tc.seed)
    params = init_params(cfg, key)
    opt = adam_init(params)
    update = make_update(cfg, tc)
    rng = np.random.default_rng(tc.seed + 1)
    losses = []
    t0 = time.time()
    for step in range(tc.steps):
        tokens = jnp.asarray(sample_batch(stream, rng, tc.batch_size, tc.seq_len))
        params, opt, loss = update(params, opt, tokens, lr_at(step, tc))
        losses.append(float(loss))
        if step % 20 == 0 or step == tc.steps - 1:
            log(f"[train {cfg.name}] step {step:4d} loss {float(loss):.4f} "
                f"({time.time() - t0:.1f}s)")
    return params, losses


def train_all(out_dir: str = "../artifacts", steps: int | None = None,
              log=print) -> None:
    import os

    tc = TRAIN if steps is None else TrainConfig(
        steps=steps, seq_len=TRAIN.seq_len, batch_size=TRAIN.batch_size,
        lr=TRAIN.lr, warmup=min(TRAIN.warmup, max(1, steps // 4)),
        seed=TRAIN.seed)
    os.makedirs(out_dir, exist_ok=True)
    stream = token_stream()
    log(f"corpus: {len(stream)} tokens")
    logs = []
    for cfg in (TARGET, DRAFT):
        params, losses = train_model(cfg, tc, stream, log=log)
        write_pdw(os.path.join(out_dir, f"weights_{cfg.name}.pdw"),
                  flatten_params(jax.device_get(params)))
        logs.append((cfg.name, losses))
    with open(os.path.join(out_dir, "train_log.txt"), "w") as f:
        for name, losses in logs:
            f.write(f"# {name}\n")
            for i, l in enumerate(losses):
                f.write(f"{i} {l:.6f}\n")


if __name__ == "__main__":
    import sys

    steps = int(sys.argv[1]) if len(sys.argv) > 1 else None
    train_all(steps=steps)
