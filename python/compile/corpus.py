"""Deterministic 6-domain build corpus.

Each domain is the synthetic analogue of one of the paper's six evaluation
datasets (DESIGN.md §inventory row 13). The domains are generated from small
template banks with a seeded RNG; they differ in template entropy, which after
training yields the cross-dataset spread of draft-model hit rates the paper's
Figs. 5-7 vary over (code/math are highly predictable, trivia/qa less so).

Domain -> paper dataset:
  code      -> HumanEval        (programming)
  math      -> GSM8K            (mathematics)
  qa        -> MMLU             (general QA)
  translate -> WMT14 DE-EN      (translation)
  trivia    -> TriviaQA-Wiki    (knowledge)
  reading   -> DROP             (reading comprehension)
"""

import random

DOMAINS = ["code", "math", "qa", "translate", "trivia", "reading"]

_NAMES = ["alice", "bob", "carol", "dave", "erin", "frank", "grace", "heidi"]
_NOUNS = ["apples", "books", "coins", "cards", "stones", "shells", "pens", "cups"]
_VERBS = ["add", "scale", "double", "square", "negate", "half", "shift", "clamp"]
_CITIES = ["paris", "london", "berlin", "madrid", "rome", "vienna", "oslo", "dublin"]
_RIVERS = ["nile", "amazon", "danube", "volga", "rhine", "seine", "thames", "ebro"]
_COLORS = ["red", "green", "blue", "amber", "violet", "teal", "gray", "white"]
_DE_EN = [
    ("der hund", "the dog"), ("die katze", "the cat"), ("das haus", "the house"),
    ("der baum", "the tree"), ("das buch", "the book"), ("die stadt", "the city"),
    ("der fluss", "the river"), ("das wasser", "the water"),
    ("die sonne", "the sun"), ("der mond", "the moon"),
]
_ADJ_DE_EN = [("gross", "big"), ("klein", "small"), ("alt", "old"), ("neu", "new"),
              ("rot", "red"), ("blau", "blue")]


def gen_code(rng: random.Random) -> str:
    f = rng.choice(_VERBS)
    a = rng.randint(1, 9)
    b = rng.randint(1, 9)
    body = {
        "add": f"return x + {a}",
        "scale": f"return x * {a}",
        "double": "return x * 2",
        "square": "return x * x",
        "negate": "return -x",
        "half": "return x // 2",
        "shift": f"return x + {a} - {b}",
        "clamp": f"return min(x, {a * 10})",
    }[f]
    return (
        f"def {f}_{a}(x):\n"
        f"    \"\"\"{f} the value x.\"\"\"\n"
        f"    {body}\n"
        f"\n"
        f"assert {f}_{a}({b}) is not None\n"
    )


def gen_math(rng: random.Random) -> str:
    n = rng.choice(_NAMES)
    o = rng.choice(_NOUNS)
    a = rng.randint(2, 20)
    b = rng.randint(2, 20)
    kind = rng.randrange(3)
    if kind == 0:
        return (
            f"question: {n} has {a} {o} and buys {b} more. how many {o} now?\n"
            f"step: {a} + {b} = {a + b}\n"
            f"answer: {a + b}\n"
        )
    if kind == 1:
        hi, lo = max(a, b), min(a, b)
        return (
            f"question: {n} had {hi} {o} and gave away {lo}. how many left?\n"
            f"step: {hi} - {lo} = {hi - lo}\n"
            f"answer: {hi - lo}\n"
        )
    return (
        f"question: {n} packs {a} boxes with {b} {o} each. total {o}?\n"
        f"step: {a} * {b} = {a * b}\n"
        f"answer: {a * b}\n"
    )


def gen_qa(rng: random.Random) -> str:
    c = rng.choice(_CITIES)
    k = rng.choice(_COLORS)
    kind = rng.randrange(3)
    if kind == 0:
        return (
            f"q: which option names a european city? (a) {k} (b) {c}\n"
            f"a: (b) {c}\n"
        )
    if kind == 1:
        return f"q: is {c} a city? options: yes, no\na: yes\n"
    return f"q: what kind of word is {k}? options: color, city\na: color\n"


def gen_translate(rng: random.Random) -> str:
    de, en = rng.choice(_DE_EN)
    ad, ae = rng.choice(_ADJ_DE_EN)
    return (
        f"de: {de} ist {ad}.\n"
        f"en: {en} is {ae}.\n"
    )


def gen_trivia(rng: random.Random) -> str:
    r = rng.choice(_RIVERS)
    c = rng.choice(_CITIES)
    length = rng.randint(2, 9) * 100
    kind = rng.randrange(2)
    if kind == 0:
        return (
            f"fact: the {r} is a river about {length} km long.\n"
            f"q: what is the {r}?\na: a river\n"
        )
    return (
        f"fact: {c} lies near the {r}.\n"
        f"q: which river is near {c}?\na: the {r}\n"
    )


def gen_reading(rng: random.Random) -> str:
    n1, n2 = rng.sample(_NAMES, 2)
    o = rng.choice(_NOUNS)
    a = rng.randint(3, 30)
    b = rng.randint(3, 30)
    return (
        f"passage: {n1} collected {a} {o} in the morning. "
        f"{n2} collected {b} {o} in the evening.\n"
        f"q: how many {o} in total?\n"
        f"a: {a} + {b} = {a + b}\n"
    )


GENERATORS = {
    "code": gen_code,
    "math": gen_math,
    "qa": gen_qa,
    "translate": gen_translate,
    "trivia": gen_trivia,
    "reading": gen_reading,
}


def build_corpus(seed: int = 7, samples_per_domain: int = 400) -> str:
    """~300 KB deterministic mixed-domain training text."""
    rng = random.Random(seed)
    chunks = []
    for i in range(samples_per_domain):
        for dom in DOMAINS:
            chunks.append(f"<{dom}>\n")
            chunks.append(GENERATORS[dom](random.Random(rng.randrange(1 << 30))))
    return "".join(chunks)


def domain_prompts(domain: str, n: int, seed: int = 99) -> list[str]:
    """Evaluation prompts: the leading part of a fresh sample (the model must
    complete the rest), one list per domain — the analogue of sampling 10
    items from each paper dataset."""
    rng = random.Random(seed * 1000 + DOMAINS.index(domain))
    prompts = []
    for _ in range(n):
        text = GENERATORS[domain](random.Random(rng.randrange(1 << 30)))
        # cut roughly in half at a line boundary so there is real continuation
        lines = text.split("\n")
        keep = max(1, len(lines) // 2)
        prompts.append(f"<{domain}>\n" + "\n".join(lines[:keep]) + "\n")
    return prompts


if __name__ == "__main__":
    c = build_corpus()
    print(f"corpus: {len(c)} chars")
    for d in DOMAINS:
        print(f"--- {d} ---")
        print(domain_prompts(d, 1)[0])
