"""pdweights: tiny binary tensor container shared with rust/src/weights/.

Layout (little-endian):
  magic   b"PDW1"
  u32     tensor count
  per tensor:
    u16   name length, name bytes (utf-8)
    u8    ndim
    u32   dims[ndim]
    f32   data (row-major)
"""

import struct

import numpy as np


def write_pdw(path: str, tensors: dict[str, np.ndarray]) -> None:
    with open(path, "wb") as f:
        f.write(b"PDW1")
        f.write(struct.pack("<I", len(tensors)))
        for name, arr in tensors.items():
            arr = np.ascontiguousarray(arr, dtype=np.float32)
            nb = name.encode()
            f.write(struct.pack("<H", len(nb)))
            f.write(nb)
            f.write(struct.pack("<B", arr.ndim))
            for d in arr.shape:
                f.write(struct.pack("<I", d))
            f.write(arr.tobytes())


def read_pdw(path: str) -> dict[str, np.ndarray]:
    out = {}
    with open(path, "rb") as f:
        assert f.read(4) == b"PDW1", "bad magic"
        (count,) = struct.unpack("<I", f.read(4))
        for _ in range(count):
            (nlen,) = struct.unpack("<H", f.read(2))
            name = f.read(nlen).decode()
            (ndim,) = struct.unpack("<B", f.read(1))
            dims = struct.unpack(f"<{ndim}I", f.read(4 * ndim))
            n = int(np.prod(dims)) if ndim else 1
            data = np.frombuffer(f.read(4 * n), dtype="<f4").reshape(dims)
            out[name] = data
    return out


def flatten_params(params) -> dict:
    """model.init_params pytree -> flat {name: array} with stable names:
    emb, final_norm, layers.<i>.<field>"""
    flat = {"emb": params["emb"], "final_norm": params["final_norm"]}
    for i, lp in enumerate(params["layers"]):
        for k, v in lp.items():
            flat[f"layers.{i}.{k}"] = v
    return flat


def unflatten_params(flat: dict, n_layers: int):
    params = {"emb": flat["emb"], "final_norm": flat["final_norm"], "layers": []}
    for i in range(n_layers):
        params["layers"].append(
            {k.split(".")[-1]: v for k, v in flat.items()
             if k.startswith(f"layers.{i}.")}
        )
    return params
