"""Shared model/artifact configuration for the PipeDec reproduction.

These constants are the single source of truth for every static shape baked
into the AOT artifacts. The Rust side reads the same values from
``artifacts/{target,draft}_config.txt`` emitted by ``aot.py`` — keep the two
in sync by only ever editing this file.
"""

from dataclasses import dataclass, field


# ---------------------------------------------------------------------------
# Vocabulary (byte-level, shared by target and draft; mirrored in
# rust/src/tokenizer/mod.rs)
# ---------------------------------------------------------------------------
PAD_ID = 0
BOS_ID = 1
EOS_ID = 2
NEWLINE_ID = 3
FIRST_PRINTABLE = 32  # ' '
LAST_PRINTABLE = 126  # '~'
# ids 4..98 map to chars 32..126; total live ids = 99, padded to 128.
VOCAB_SIZE = 128

# ---------------------------------------------------------------------------
# Static shape caps baked into artifacts
# ---------------------------------------------------------------------------
WIDTH_CAP = 32        # max tree-layer width W the real engine supports
TREE_CAP = 288        # tree-level KV cache capacity (nodes)
PAST_CAP = 512        # model-level KV cache capacity (accepted tokens)
PREFILL_CHUNK = 32    # prompt tokens processed per prefill stage call
NEG_INF = -1e9        # additive mask value


@dataclass(frozen=True)
class ModelConfig:
    """LLaMA-style decoder configuration."""

    name: str
    dim: int
    n_layers: int
    n_heads: int
    vocab_size: int = VOCAB_SIZE
    mlp_hidden: int = 0  # 0 -> 3 * dim (SwiGLU ~ 8/3 rounded)
    rope_theta: float = 10000.0
    norm_eps: float = 1e-5

    @property
    def head_dim(self) -> int:
        assert self.dim % self.n_heads == 0
        return self.dim // self.n_heads

    @property
    def hidden(self) -> int:
        return self.mlp_hidden if self.mlp_hidden else 3 * self.dim

    def param_count(self) -> int:
        d, h, v = self.dim, self.hidden, self.vocab_size
        per_layer = 4 * d * d + 3 * d * h + 2 * d
        return v * d + self.n_layers * per_layer + d  # tied head


# The "large" model: 8 layers so the real engine can run 1/2/4/8-stage
# pipelines; paper-scale 7/14/21-stage numbers come from the simulator.
TARGET = ModelConfig(name="target", dim=128, n_layers=8, n_heads=4)

# The draft model: cheaper and less accurate, co-trained on the same corpus.
DRAFT = ModelConfig(name="draft", dim=64, n_layers=2, n_heads=2)


@dataclass(frozen=True)
class TrainConfig:
    seq_len: int = 96
    batch_size: int = 8
    steps: int = 240
    lr: float = 3e-3
    warmup: int = 20
    seed: int = 1234
    weight_decay: float = 0.01
    grad_clip: float = 1.0


TRAIN = TrainConfig()


def config_lines(cfg: ModelConfig) -> str:
    """key=value dump consumed by rust/src/config/artifact.rs."""
    return "".join(
        f"{k}={v}\n"
        for k, v in [
            ("name", cfg.name),
            ("dim", cfg.dim),
            ("n_layers", cfg.n_layers),
            ("n_heads", cfg.n_heads),
            ("head_dim", cfg.head_dim),
            ("mlp_hidden", cfg.hidden),
            ("vocab_size", cfg.vocab_size),
            ("rope_theta", cfg.rope_theta),
            ("norm_eps", cfg.norm_eps),
            ("width_cap", WIDTH_CAP),
            ("tree_cap", TREE_CAP),
            ("past_cap", PAST_CAP),
            ("prefill_chunk", PREFILL_CHUNK),
        ]
    )
