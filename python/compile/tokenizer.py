"""Byte-level tokenizer shared with rust/src/tokenizer/mod.rs.

ids: 0=PAD 1=BOS 2=EOS 3='\n', 4..98 = printable ASCII 32..126.
Anything outside the alphabet maps to ' '.
"""

from .configs import (
    BOS_ID,
    EOS_ID,
    FIRST_PRINTABLE,
    LAST_PRINTABLE,
    NEWLINE_ID,
    PAD_ID,
)

_OFFSET = 4


def encode(text: str, bos: bool = False, eos: bool = False) -> list[int]:
    ids = [BOS_ID] if bos else []
    for ch in text:
        if ch == "\n":
            ids.append(NEWLINE_ID)
        else:
            o = ord(ch)
            if FIRST_PRINTABLE <= o <= LAST_PRINTABLE:
                ids.append(o - FIRST_PRINTABLE + _OFFSET)
            else:
                ids.append(ord(" ") - FIRST_PRINTABLE + _OFFSET)
    if eos:
        ids.append(EOS_ID)
    return ids


def decode(ids) -> str:
    out = []
    for i in ids:
        i = int(i)
        if i == NEWLINE_ID:
            out.append("\n")
        elif i >= _OFFSET and i < _OFFSET + (LAST_PRINTABLE - FIRST_PRINTABLE + 1):
            out.append(chr(i - _OFFSET + FIRST_PRINTABLE))
        elif i in (PAD_ID, BOS_ID, EOS_ID):
            continue
    return "".join(out)
