"""AOT entry point: train (if needed) + lower every serve-time entry point to
HLO text artifacts consumed by the Rust runtime.

HLO *text* (not serialized HloModuleProto) is the interchange format: jax
>= 0.5 emits protos with 64-bit instruction ids which xla_extension 0.5.1
(the version behind the published ``xla`` crate) rejects; the text parser
reassigns ids (see /opt/xla-example/README.md).

Artifact set (per model m in {target, draft}):
  {m}_embed.hlo.txt   (emb[V,d], tokens[W]i32)                  -> (h[W,d],)
  {m}_layer.hlo.txt   (9 layer weights, h, past_k, past_v, tree_k, tree_v,
                       tree_len i32, pos[W]i32, past_bias, tree_bias)
                      -> (h', k_new[H,W,hd], v_new[H,W,hd])
  {m}_head.hlo.txt    (final_norm[d], emb[V,d], h[W,d])          -> (logits,)
plus the device-side KV update entry points (kvops.py; argument 0 is
donated, single untupled output so the runtime can keep it resident):
  {m}_kvapp_past.hlo.txt  (dst[H,P,hd], src[H,W,hd], start, count) -> dst'
  {m}_kvapp_tree.hlo.txt  (dst[H,T,hd], src[H,W,hd], start, count) -> dst'
  {m}_kvprom.hlo.txt      (dst[H,P,hd], src[H,T,hd], slot, pos)    -> dst'
  {m}_kvcompact.hlo.txt   (dst[H,T,hd], idx[T]i32)                 -> dst'
plus weights_{m}.pdw, {m}_config.txt, prompts_{domain}.txt, manifest.txt.

Argument order is the lowering order below and is mirrored by
rust/src/model/stage.rs — do not reorder.
"""

import argparse
import functools
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import corpus
from .configs import (
    DRAFT, PAST_CAP, TARGET, TREE_CAP, WIDTH_CAP, ModelConfig, config_lines,
)
from .kvops import lower_kv_append, lower_kv_gather, lower_kv_promote
from .model import embed_step, head_step, layer_step


def to_hlo_text(lowered, return_tuple: bool = True) -> str:
    """The model entry points return tuples; the kv update entry points are
    lowered untupled (``return_tuple=False``) so the single output buffer
    can alias the donated argument — a tupled root would force the runtime
    through a host-side tuple decompose, defeating residency. Donation
    annotations (``input_output_alias``) survive this conversion in both
    modes."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=return_tuple
    )
    return comp.as_hlo_text()


def f32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def i32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.int32)


# Width buckets: the default artifacts use the full WIDTH_CAP; a W=8 variant
# (suffix `_w8`) serves small-tree engine configs so they do not pay the
# padded 32-wide compute (EXPERIMENTS.md §Perf iteration 3).
WIDTH_BUCKETS = (WIDTH_CAP, 8)


def lower_embed(cfg: ModelConfig, w: int = WIDTH_CAP):
    return jax.jit(embed_step).lower(
        f32(cfg.vocab_size, cfg.dim), i32(w))


def lower_head(cfg: ModelConfig, w: int = WIDTH_CAP):
    fn = functools.partial(head_step, eps=cfg.norm_eps)
    return jax.jit(fn).lower(
        f32(cfg.dim), f32(cfg.vocab_size, cfg.dim), f32(w, cfg.dim))


def lower_layer(cfg: ModelConfig, w: int = WIDTH_CAP):
    d, h = cfg.dim, cfg.hidden
    nh, hd = cfg.n_heads, cfg.head_dim
    fn = functools.partial(layer_step, cfg=cfg, use_kernel=True)
    return jax.jit(fn).lower(
        # weights (LAYER_WEIGHT_ORDER)
        f32(d), f32(d, d), f32(d, d), f32(d, d), f32(d, d),
        f32(d), f32(d, h), f32(d, h), f32(h, d),
        # runtime
        f32(w, d),                         # h
        f32(nh, PAST_CAP, hd),             # past_k
        f32(nh, PAST_CAP, hd),             # past_v
        f32(nh, TREE_CAP, hd),             # tree_k (without current block)
        f32(nh, TREE_CAP, hd),             # tree_v
        i32(),                             # tree_len
        i32(w),                            # pos
        f32(w, PAST_CAP),                  # past_bias
        f32(w, TREE_CAP),                  # tree_bias
    )


def emit(out_dir: str, name: str, lowered, manifest: list,
         return_tuple: bool = True) -> None:
    text = to_hlo_text(lowered, return_tuple=return_tuple)
    path = os.path.join(out_dir, f"{name}.hlo.txt")
    with open(path, "w") as f:
        f.write(text)
    manifest.append(f"{name}.hlo.txt {len(text)}")
    print(f"  {name}.hlo.txt ({len(text) // 1024} KiB)")


def emit_prompts(out_dir: str, per_domain: int = 12) -> None:
    for dom in corpus.DOMAINS:
        path = os.path.join(out_dir, f"prompts_{dom}.txt")
        with open(path, "w") as f:
            f.write("\n%%%\n".join(corpus.domain_prompts(dom, per_domain)))


GOLDEN_PROMPT = "<math>\nquestion: alice has 4 apples and buys 3 more. how many apples now?\n"
GOLDEN_STEPS = 12


def emit_golden(out_dir: str) -> None:
    """Greedy continuations computed with the python training-path forward;
    rust/tests/integration_runtime.rs replays them through the AOT artifacts
    to prove the two paths agree bit-for-bit at the argmax level."""
    import jax.numpy as jnp
    import numpy as np

    from . import tokenizer
    from .model import forward_train
    from .pdw import read_pdw, unflatten_params

    ids = tokenizer.encode(GOLDEN_PROMPT)
    for cfg in (TARGET, DRAFT):
        flat = read_pdw(os.path.join(out_dir, f"weights_{cfg.name}.pdw"))
        params = unflatten_params(flat, cfg.n_layers)
        fwd = jax.jit(lambda p, t, c=cfg: forward_train(p, t, c))
        seq = list(ids)
        outs = []
        for _ in range(GOLDEN_STEPS):
            logits = fwd(params, jnp.asarray(np.array(seq)[None], jnp.int32))
            nxt = int(jnp.argmax(logits[0, -1]))
            outs.append(nxt)
            seq.append(nxt)
        with open(os.path.join(out_dir, f"golden_{cfg.name}.txt"), "w") as f:
            f.write(" ".join(str(i) for i in ids) + "\n")
            f.write(" ".join(str(i) for i in outs) + "\n")
        print(f"  golden_{cfg.name}.txt: {outs}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--train-steps", type=int, default=None,
                    help="override training steps (smoke tests use ~30)")
    ap.add_argument("--retrain", action="store_true")
    args = ap.parse_args()
    out = args.out
    os.makedirs(out, exist_ok=True)

    need_train = args.retrain or not all(
        os.path.exists(os.path.join(out, f"weights_{m.name}.pdw"))
        for m in (TARGET, DRAFT))
    if need_train:
        from .train import train_all

        train_all(out_dir=out, steps=args.train_steps)
    else:
        print("weights exist, skipping training (use --retrain to redo)")

    manifest: list[str] = []
    for cfg in (TARGET, DRAFT):
        print(f"lowering {cfg.name} ({cfg.param_count() / 1e6:.2f}M params)")
        for w in WIDTH_BUCKETS:
            sfx = "" if w == WIDTH_CAP else f"_w{w}"
            emit(out, f"{cfg.name}_embed{sfx}", lower_embed(cfg, w), manifest)
            emit(out, f"{cfg.name}_layer{sfx}", lower_layer(cfg, w), manifest)
            emit(out, f"{cfg.name}_head{sfx}", lower_head(cfg, w), manifest)
            # device-side KV append (donated arg 0, untupled output); the
            # src block is width-bucketed like the layer output it carries
            emit(out, f"{cfg.name}_kvapp_past{sfx}",
                 lower_kv_append(cfg, PAST_CAP, w), manifest, return_tuple=False)
            emit(out, f"{cfg.name}_kvapp_tree{sfx}",
                 lower_kv_append(cfg, TREE_CAP, w), manifest, return_tuple=False)
        # promotion / compaction are width-independent: one each per model
        emit(out, f"{cfg.name}_kvprom", lower_kv_promote(cfg), manifest,
             return_tuple=False)
        emit(out, f"{cfg.name}_kvcompact", lower_kv_gather(cfg), manifest,
             return_tuple=False)
        with open(os.path.join(out, f"{cfg.name}_config.txt"), "w") as f:
            f.write(config_lines(cfg))
    emit_prompts(out)
    emit_golden(out)
    with open(os.path.join(out, "manifest.txt"), "w") as f:
        f.write("\n".join(manifest) + "\n")
    print("aot done")


if __name__ == "__main__":
    main()
