"""Device-side KV cache update entry points (ROADMAP "device-side KV
append").

PJRT buffers are immutable, so until these ops existed every accepted
token's promotion re-uploaded the full past tensors and every tree
expansion re-uploaded the tree tensors (EXPERIMENTS.md §Perf iteration 4,
"known limits"). Each op here is lowered with **argument 0 donated**
(``donate_argnums=(0,)``), which emits an ``input_output_alias`` entry in
the HLO module header: the runtime may reuse the donated input buffer for
the output, so the Rust mirror updates a resident KV tensor in place for
O(appended rows) upload bytes instead of O(capacity).

All three ops are written as mask/gather formulations rather than
``dynamic_update_slice`` because XLA *clamps* DUS start indices — a
partially-valid block appended near capacity would silently shift instead
of failing. The mask form writes exactly rows ``[start, start+count)`` and
reproduces the host cache's semantics bit-for-bit, including leaving
rows outside the written range untouched (stale rows are bias-masked, and
the conformance tests in ``rust/tests/kvcache_device.rs`` compare full
tensors against the host mirror).

Shapes (per model config; see ``lower_*`` below):

  kv_append   dst[H, CAP, hd], src[H, W, hd], start i32, count i32 -> dst'
  kv_promote  dst[H, P, hd],   src[H, T, hd], slot i32, pos i32    -> dst'
  kv_gather   dst[H, T, hd],   idx[T] i32                          -> dst'

``kv_append`` serves both levels (CAP ∈ {PAST_CAP, TREE_CAP}) and is
width-bucketed like the layer artifact; ``kv_promote`` (tree root ->
past row) and ``kv_gather`` (tree compaction through a full-capacity
index vector, identity beyond the keep prefix) are width-independent.
"""

import jax
import jax.numpy as jnp

from .configs import PAST_CAP, TREE_CAP, ModelConfig


def kv_append(dst, src, start, count):
    """Write ``src`` rows ``[0, count)`` into ``dst`` rows
    ``[start, start+count)``; all other rows pass through unchanged."""
    cap, w = dst.shape[1], src.shape[1]
    rows = jax.lax.iota(jnp.int32, cap)
    mask = (rows >= start) & (rows < start + count)
    idx = jnp.clip(rows - start, 0, w - 1)
    cand = jnp.take(src, idx, axis=1)
    return jnp.where(mask[None, :, None], cand, dst)


def kv_promote(dst, src, slot, pos):
    """Copy ``src`` row ``slot`` into ``dst`` row ``pos`` (the §3.4.3
    tree-root -> model-level promotion, one row per layer per token)."""
    p = dst.shape[1]
    rows = jax.lax.iota(jnp.int32, p)
    row = jax.lax.dynamic_slice_in_dim(src, slot, 1, axis=1)  # [H, 1, hd]
    return jnp.where((rows == pos)[None, :, None], row, dst)


def kv_gather(dst, idx):
    """Compact ``dst`` through a full-capacity row index vector. The keep
    prefix carries the surviving slots; padding the suffix with the
    identity (``idx[i] = i``) leaves those rows bit-identical to the host
    cache's in-place compaction, which never touches them."""
    return jnp.take(dst, idx, axis=1)


def _f32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def _i32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.int32)


def lower_kv_append(cfg: ModelConfig, cap: int, w: int):
    """Append a ``[H, w, hd]`` block into a capacity-``cap`` level tensor."""
    nh, hd = cfg.n_heads, cfg.head_dim
    return jax.jit(kv_append, donate_argnums=(0,)).lower(
        _f32(nh, cap, hd), _f32(nh, w, hd), _i32(), _i32())


def lower_kv_promote(cfg: ModelConfig):
    nh, hd = cfg.n_heads, cfg.head_dim
    return jax.jit(kv_promote, donate_argnums=(0,)).lower(
        _f32(nh, PAST_CAP, hd), _f32(nh, TREE_CAP, hd), _i32(), _i32())


def lower_kv_gather(cfg: ModelConfig):
    nh, hd = cfg.n_heads, cfg.head_dim
    return jax.jit(kv_gather, donate_argnums=(0,)).lower(
        _f32(nh, TREE_CAP, hd), _i32(TREE_CAP))
