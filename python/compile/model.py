"""L2: LLaMA-style decoder with dynamic tree attention (paper §3.4.2).

Two families of entry points:

* build-time training path: ``forward_train`` — plain causal attention over
  [B, S] token batches (never exported);
* serve-time path, lowered to HLO by ``aot.py`` and driven from Rust:
    - ``embed_step``   tokens[W]                      -> h[W, d]
    - ``layer_step``   h + two-level KV + masks       -> h', new-block KV
    - ``head_step``    h[W, d]                        -> logits[W, V]

``layer_step`` implements one transformer block around the L1 Pallas tree
attention kernel. The same program serves decode *and* prefill: prefill is a
decode call with an empty tree cache and a causal in-block bias (the current
chunk plays the role of the "predicted" segment of Alg. 1).

Weight argument order is fixed and mirrored in rust/src/model/stage.rs:
  attn_norm, wq, wk, wv, wo, mlp_norm, w_gate, w_up, w_down
"""

import jax
import jax.numpy as jnp

from .configs import ModelConfig, NEG_INF
from .kernels.tree_attention import tree_attention
from .kernels.ref import tree_attention_ref_mha

# ---------------------------------------------------------------------------
# parameter init
# ---------------------------------------------------------------------------


def init_params(cfg: ModelConfig, key):
    """LLaMA-style init; head tied to the embedding."""
    d, hdim, v = cfg.dim, cfg.hidden, cfg.vocab_size
    keys = jax.random.split(key, 1 + cfg.n_layers)
    scale = d ** -0.5
    params = {
        "emb": jax.random.normal(keys[0], (v, d), jnp.float32) * scale,
        "final_norm": jnp.ones((d,), jnp.float32),
        "layers": [],
    }
    for li in range(cfg.n_layers):
        ks = jax.random.split(keys[1 + li], 7)
        params["layers"].append(
            {
                "attn_norm": jnp.ones((d,), jnp.float32),
                "wq": jax.random.normal(ks[0], (d, d), jnp.float32) * scale,
                "wk": jax.random.normal(ks[1], (d, d), jnp.float32) * scale,
                "wv": jax.random.normal(ks[2], (d, d), jnp.float32) * scale,
                "wo": jax.random.normal(ks[3], (d, d), jnp.float32) * scale,
                "mlp_norm": jnp.ones((d,), jnp.float32),
                "w_gate": jax.random.normal(ks[4], (d, hdim), jnp.float32) * scale,
                "w_up": jax.random.normal(ks[5], (d, hdim), jnp.float32) * scale,
                "w_down": jax.random.normal(ks[6], (hdim, d), jnp.float32)
                * hdim ** -0.5,
            }
        )
    return params


LAYER_WEIGHT_ORDER = (
    "attn_norm", "wq", "wk", "wv", "wo", "mlp_norm", "w_gate", "w_up", "w_down",
)

# ---------------------------------------------------------------------------
# building blocks
# ---------------------------------------------------------------------------


def rms_norm(x, w, eps):
    return x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps) * w


def rope(x, pos, theta):
    """x: [..., T, H, hd] or [T, H, hd]; pos: [T] int32 absolute positions."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = pos.astype(jnp.float32)[..., :, None] * freqs  # [T, half]
    cos = jnp.cos(ang)[..., :, None, :]  # [T, 1, half]
    sin = jnp.sin(ang)[..., :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)


def swiglu(h, w_gate, w_up, w_down):
    return (jax.nn.silu(h @ w_gate) * (h @ w_up)) @ w_down

# ---------------------------------------------------------------------------
# serve-time entry points (exported by aot.py)
# ---------------------------------------------------------------------------


def embed_step(emb, tokens):
    """tokens: [W] i32 -> [W, d]."""
    return (jnp.take(emb, tokens, axis=0),)


def head_step(final_norm, emb, h, eps):
    """h: [W, d] -> logits [W, V] (tied head)."""
    return (rms_norm(h, final_norm, eps) @ emb.T,)


def layer_step(
    attn_norm, wq, wk, wv, wo, mlp_norm, w_gate, w_up, w_down,
    h, past_k, past_v, tree_k, tree_v, tree_len, pos, past_bias, tree_bias,
    *, cfg: ModelConfig, use_kernel: bool = True,
):
    """One transformer block with dynamic tree attention.

    h:          [W, d]      hidden states of the newest tree layer
    past_k/v:   [H, P, hd]  model-level cache (accepted tokens), masked by
                            past_bias
    tree_k/v:   [H, T, hd]  tree-level cache WITHOUT the current block; the
                            block is appended at tree_len inside (Alg. 1
                            "cache.append")
    tree_len:   i32 scalar  number of valid entries already in the tree cache
    pos:        [W] i32     absolute RoPE positions of the new nodes
    past_bias:  [W, P] f32  additive validity mask
    tree_bias:  [W, T] f32  additive ancestor mask (covers appended block too)

    Returns (h_out [W, d], k_new [H, W, hd], v_new [H, W, hd]); the caller
    owns both caches and appends k_new/v_new to its tree-level cache.
    """
    nh, hd, eps = cfg.n_heads, cfg.head_dim, cfg.norm_eps
    w = h.shape[0]

    x = rms_norm(h, attn_norm, eps)
    q = (x @ wq).reshape(w, nh, hd)
    k = (x @ wk).reshape(w, nh, hd)
    v = (x @ wv).reshape(w, nh, hd)
    q = rope(q, pos, cfg.rope_theta)
    k = rope(k, pos, cfg.rope_theta)

    k_new = jnp.transpose(k, (1, 0, 2))  # [H, W, hd]
    v_new = jnp.transpose(v, (1, 0, 2))

    # Alg. 1 line 3: append the block to the tree-level cache at tree_len.
    tk = jax.lax.dynamic_update_slice(tree_k, k_new, (0, tree_len, 0))
    tv = jax.lax.dynamic_update_slice(tree_v, v_new, (0, tree_len, 0))

    qh = jnp.transpose(q, (1, 0, 2))  # [H, W, hd]
    attn_fn = tree_attention if use_kernel else tree_attention_ref_mha
    a = attn_fn(qh, past_k, past_v, tk, tv, past_bias, tree_bias)  # [H, W, hd]
    a = jnp.transpose(a, (1, 0, 2)).reshape(w, nh * hd)
    h = h + a @ wo

    x = rms_norm(h, mlp_norm, eps)
    h = h + swiglu(x, w_gate, w_up, w_down)
    return h, k_new, v_new

# ---------------------------------------------------------------------------
# bias helpers (mirrored in rust/src/model/bias.rs; python versions are used
# by tests and by the hit-rate measurement path in aot.py)
# ---------------------------------------------------------------------------


def past_bias_for(past_len, w, p):
    """[W, P]: column j valid iff j < past_len."""
    cols = jnp.arange(p)[None, :]
    row = jnp.where(cols < past_len, 0.0, NEG_INF).astype(jnp.float32)
    return jnp.broadcast_to(row, (w, p))


def causal_block_bias(valid, tree_len, w, t):
    """Prefill-mode tree bias: block rows attend causally to the block
    appended at tree_len; rows >= valid are fully masked except self."""
    rows = jnp.arange(w)[:, None]
    cols = jnp.arange(t)[None, :]
    in_block = (cols >= tree_len) & (cols < tree_len + w)
    causal = cols - tree_len <= rows
    ok = in_block & causal & (rows < valid)
    self_ok = in_block & (cols - tree_len == rows)
    return jnp.where(ok | self_ok, 0.0, NEG_INF).astype(jnp.float32)

# ---------------------------------------------------------------------------
# training path (build-time only)
# ---------------------------------------------------------------------------


def forward_train(params, tokens, cfg: ModelConfig):
    """tokens: [B, S] i32 -> logits [B, S, V]; plain causal attention."""
    b, s = tokens.shape
    nh, hd, eps = cfg.n_heads, cfg.head_dim, cfg.norm_eps
    h = jnp.take(params["emb"], tokens, axis=0)  # [B, S, d]
    pos = jnp.arange(s, dtype=jnp.int32)
    causal = jnp.where(
        jnp.arange(s)[None, :] <= jnp.arange(s)[:, None], 0.0, NEG_INF
    ).astype(jnp.float32)
    for lp in params["layers"]:
        x = rms_norm(h, lp["attn_norm"], eps)
        q = (x @ lp["wq"]).reshape(b, s, nh, hd)
        k = (x @ lp["wk"]).reshape(b, s, nh, hd)
        v = (x @ lp["wv"]).reshape(b, s, nh, hd)
        q = rope(q, pos, cfg.rope_theta)
        k = rope(k, pos, cfg.rope_theta)
        q = jnp.transpose(q, (0, 2, 1, 3))  # [B, H, S, hd]
        k = jnp.transpose(k, (0, 2, 1, 3))
        v = jnp.transpose(v, (0, 2, 1, 3))
        sc = jnp.einsum("bhqd,bhkd->bhqk", q, k) / jnp.sqrt(float(hd))
        sc = sc + causal[None, None]
        a = jax.nn.softmax(sc, axis=-1)
        o = jnp.einsum("bhqk,bhkd->bhqd", a, v)
        o = jnp.transpose(o, (0, 2, 1, 3)).reshape(b, s, nh * hd)
        h = h + o @ lp["wo"]
        x = rms_norm(h, lp["mlp_norm"], eps)
        h = h + swiglu(x, lp["w_gate"], lp["w_up"], lp["w_down"])
    h = rms_norm(h, params["final_norm"], eps)
    return h @ params["emb"].T


def loss_fn(params, tokens, cfg: ModelConfig):
    """Next-token cross-entropy, PAD positions excluded."""
    logits = forward_train(params, tokens[:, :-1], cfg)
    targets = tokens[:, 1:]
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    mask = (targets != 0).astype(jnp.float32)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
