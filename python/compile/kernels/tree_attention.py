"""L1 Pallas kernel: dynamic tree attention (paper Alg. 1).

TPU adaptation of the paper's GPU algorithm (DESIGN.md §Hardware-Adaptation):

* grid = (n_heads,): one program instance per head; every per-head operand
  tile fits comfortably in VMEM at the paper-relevant sizes
  (W<=128, P<=512, T<=288, hd<=64 -> < 1 MiB of f32 per instance, ~6% of a
  16 MiB VMEM), so no inner K-loop is needed and both matmuls map to single
  MXU passes.
* the two segments (model cache ‖ tree cache) are reduced with a shared
  online-softmax accumulator instead of being concatenated — the paper's
  "compute S_past and S_predict separately" trick; on TPU this avoids
  materializing [W, P+T] in VMEM.
* masks arrive as dense additive bias tiles (0 / -1e9) resident in VMEM; no
  gather/scatter — the dynamic tree structure is encoded entirely in the
  bias, which the Rust coordinator rebuilds incrementally per timestep.

interpret=True is mandatory here: the CPU PJRT plugin cannot execute Mosaic
custom calls, and interpret mode traces the kernel into plain HLO so the
whole stage artifact stays loadable by the Rust runtime.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _tree_attn_kernel(q_ref, pk_ref, pv_ref, tk_ref, tv_ref,
                      pb_ref, tb_ref, o_ref):
    """One head. Shapes: q [W,hd], pk/pv [P,hd], tk/tv [T,hd],
    pb [W,P], tb [W,T], o [W,hd]."""
    q = q_ref[...]
    hd = q.shape[-1]
    scale = 1.0 / jnp.sqrt(jnp.asarray(hd, dtype=q.dtype))

    # --- segment 1: model-level (past) cache ---
    s_past = jnp.dot(q, pk_ref[...].T) * scale + pb_ref[...]
    m1 = jnp.max(s_past, axis=-1, keepdims=True)                   # [W,1]
    e1 = jnp.exp(s_past - m1)
    d1 = jnp.sum(e1, axis=-1, keepdims=True)
    a1 = jnp.dot(e1, pv_ref[...])                                  # [W,hd]

    # --- segment 2: tree-level cache (current block already appended) ---
    s_tree = jnp.dot(q, tk_ref[...].T) * scale + tb_ref[...]
    m2 = jnp.max(s_tree, axis=-1, keepdims=True)
    e2 = jnp.exp(s_tree - m2)
    d2 = jnp.sum(e2, axis=-1, keepdims=True)
    a2 = jnp.dot(e2, tv_ref[...])

    # --- online-softmax merge of the two segments ---
    m = jnp.maximum(m1, m2)
    c1 = jnp.exp(m1 - m)
    c2 = jnp.exp(m2 - m)
    denom = d1 * c1 + d2 * c2
    o_ref[...] = (a1 * c1 + a2 * c2) / denom


@functools.partial(jax.named_call, name="tree_attention")
def tree_attention(q, past_k, past_v, tree_k, tree_v, past_bias, tree_bias):
    """Multi-head dynamic tree attention.

    q:         [H, W, hd]
    past_k/v:  [H, P, hd]
    tree_k/v:  [H, T, hd]  (current block appended at tree_len by caller)
    past_bias: [W, P]      additive validity mask
    tree_bias: [W, T]      additive ancestor mask
    returns:   [H, W, hd]
    """
    h, w, hd = q.shape
    p = past_k.shape[1]
    t = tree_k.shape[1]

    kernel = pl.pallas_call(
        _tree_attn_kernel,
        grid=(h,),
        in_specs=[
            pl.BlockSpec((None, w, hd), lambda i: (i, 0, 0)),
            pl.BlockSpec((None, p, hd), lambda i: (i, 0, 0)),
            pl.BlockSpec((None, p, hd), lambda i: (i, 0, 0)),
            pl.BlockSpec((None, t, hd), lambda i: (i, 0, 0)),
            pl.BlockSpec((None, t, hd), lambda i: (i, 0, 0)),
            pl.BlockSpec((w, p), lambda i: (0, 0)),
            pl.BlockSpec((w, t), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((None, w, hd), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((h, w, hd), q.dtype),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )
    return kernel(q, past_k, past_v, tree_k, tree_v, past_bias, tree_bias)


def vmem_estimate_bytes(w, p, t, hd, dtype_bytes=4):
    """Per-instance VMEM footprint estimate (DESIGN/EXPERIMENTS §Perf):
    operand tiles + both score tiles + accumulators."""
    tiles = (
        w * hd            # q
        + 2 * p * hd      # pk, pv
        + 2 * t * hd      # tk, tv
        + w * p + w * t   # biases
        + w * p + w * t   # score/exp temporaries
        + 3 * w * hd      # a1, a2, out
    )
    return tiles * dtype_bytes
