"""Pure-jnp oracle for the dynamic tree attention kernel (paper Alg. 1).

Computes, per head:
    S_past    = Q K_past^T  / sqrt(hd)   + past_bias   (valid-length mask)
    S_tree    = Q K_tree^T  / sqrt(hd)   + tree_bias   (ancestor mask)
    S         = softmax([S_past ; S_tree])             (joint normalization)
    A         = S_past V_past + S_tree V_tree

The tree cache already contains the current block appended at ``tree_len``
(append happens in L2 before the kernel — "cache.append" of Alg. 1), and the
biases are additive 0/-1e9 masks computed host-side, so the kernel itself is
branch-free and static-shaped.
"""

import jax.numpy as jnp


def tree_attention_ref(q, past_k, past_v, tree_k, tree_v, past_bias, tree_bias):
    """All arrays are per-head slices:

    q:         [W, hd]
    past_k/v:  [P, hd]
    tree_k/v:  [T, hd]
    past_bias: [W, P]  additive (0 valid / -1e9 invalid)
    tree_bias: [W, T]  additive ancestor mask
    returns    [W, hd]
    """
    hd = q.shape[-1]
    scale = 1.0 / jnp.sqrt(jnp.asarray(hd, dtype=q.dtype))
    s_past = q @ past_k.T * scale + past_bias
    s_tree = q @ tree_k.T * scale + tree_bias
    s = jnp.concatenate([s_past, s_tree], axis=-1)
    s = jnp.exp(s - jnp.max(s, axis=-1, keepdims=True))
    s = s / jnp.sum(s, axis=-1, keepdims=True)
    p = past_k.shape[0]
    return s[:, :p] @ past_v + s[:, p:] @ tree_v


def tree_attention_ref_mha(q, past_k, past_v, tree_k, tree_v, past_bias, tree_bias):
    """Multi-head variant: q [H, W, hd], caches [H, P/T, hd], biases shared
    across heads ([W, P], [W, T])."""
    import jax

    return jax.vmap(
        tree_attention_ref, in_axes=(0, 0, 0, 0, 0, None, None)
    )(q, past_k, past_v, tree_k, tree_v, past_bias, tree_bias)
